//! Graph-aware analyses: transitive panic-freedom, unguarded indexing,
//! float-determinism over hash iteration, transitive no-FMA, and module
//! layering.
//!
//! These run on top of the call graph and module graph from
//! [`crate::graph`], complementing the per-line rules in [`crate::rules`]:
//!
//! - `serve-no-panic` — seeded at `Engine::serve`, `decode_step_batch`,
//!   the public `ExpertStore` surface, and every public fn under
//!   `rust/src/serve/` (which picks up new serve surface automatically:
//!   `Engine::serve_timed`, the streaming `StreamSink` API, the
//!   `workload` generator/trace-replay fns); any *reachable* non-test
//!   function containing a
//!   panic-family op (`panic!`/`todo!`/`unreachable!`/`unimplemented!`,
//!   `.expect(…)`, non-poison `.unwrap()`) is flagged, with the call
//!   chain that reaches it. This replaces the old path-prefix heuristic:
//!   a panic three crates-worth of calls below `serve/` is just as fatal
//!   mid-batch as one written in `serve/engine.rs`.
//! - `serve-unguarded-index` — a reachable function that indexes slices
//!   must carry a bounds guard somewhere in its body (an assert-family
//!   macro, or a `.len(`/`.is_empty(` check feeding its control flow).
//!   Guarding is judged per function, not per site: kernels assert their
//!   dimension contract once and then index freely.
//! - `float-hash-order` — `for` iteration over a `HashMap`/`HashSet`
//!   whose body accumulates into an `f32`/`f64` (or a
//!   `.sum::<f32>()` chain hanging off a hash receiver). Iteration order
//!   is nondeterministic, so the accumulation order — and with float
//!   rounding, the result — varies run to run, silently breaking the
//!   bitwise-invariance contract.
//! - `no-fma-transitive` — extends `no-fma` from tokens to reachability:
//!   anything reachable from the kernel contract files (`tensor/simd.rs`,
//!   `tensor/matmul.rs`, `tensor/ops.rs`, `quant/fused.rs`) must stay
//!   FMA-free. Inline `xtask-allow: no-fma` markers do *not* exempt this
//!   rule (only the pinned region in `tensor/simd.rs` does): an allow
//!   placed on a helper must not silently launder FMA into the contract.
//! - `module-layering` — the `use`/path graph between top-level modules
//!   must match the allowed-edges manifest (`rust/xtask/layering.toml`)
//!   and stay acyclic.

use crate::graph::{CallGraph, ModuleGraph};
use crate::items::FileItems;
use crate::lexer::{Tok, TokKind};
use crate::rules::Finding;
use crate::scan::SourceFile;
use std::collections::{BTreeMap, HashMap, HashSet};

/// One scanned + extracted file with its allow masks, ready for analysis.
pub struct Prepared {
    pub sf: SourceFile,
    pub items: FileItems,
    pub allow: HashMap<&'static str, Vec<bool>>,
}

/// Files reachable code must not fuse from: the kernel contract region.
const FMA_SEED_FILES: &[&str] = &[
    "rust/src/tensor/simd.rs",
    "rust/src/tensor/matmul.rs",
    "rust/src/tensor/ops.rs",
    "rust/src/quant/fused.rs",
];

const PANIC_MACROS: &[&str] = &["panic", "unreachable", "todo", "unimplemented"];
const ASSERT_MACROS: &[&str] =
    &["assert", "assert_eq", "assert_ne", "debug_assert", "debug_assert_eq", "debug_assert_ne"];

/// Run every graph analysis. `require_seeds` makes an empty seed set a
/// hard error (the real tree must always have its entry points; a fixture
/// tree that lost them is a broken fixture).
pub fn run(
    files: &[Prepared],
    manifest: Option<&Manifest>,
    require_seeds: bool,
) -> Result<Vec<Finding>, String> {
    // Graph scope: production sources only.
    let graph_files: Vec<&Prepared> =
        files.iter().filter(|p| p.items.rel.starts_with("rust/src/")).collect();
    let items: Vec<&FileItems> = graph_files.iter().map(|p| &p.items).collect();
    let graph = CallGraph::build(&items);

    let mut findings: Vec<Finding> = Vec::new();

    // --- Seeds -----------------------------------------------------------
    let mut serve_seeds: Vec<usize> = Vec::new();
    let mut have_engine_serve = false;
    let mut have_decode = false;
    let mut have_store = false;
    for (id, node) in graph.nodes.iter().enumerate() {
        let f = &items[node.file].fns[node.item];
        if f.is_test {
            continue;
        }
        let is_engine_serve = f.impl_type.as_deref() == Some("Engine") && f.name == "serve";
        let is_decode = f.name == "decode_step_batch";
        let is_store = f.impl_type.as_deref() == Some("ExpertStore") && f.is_pub;
        let is_serve_pub = items[node.file].rel.starts_with("rust/src/serve/") && f.is_pub;
        have_engine_serve |= is_engine_serve;
        have_decode |= is_decode;
        have_store |= is_store;
        if is_engine_serve || is_decode || is_store || is_serve_pub {
            serve_seeds.push(id);
        }
    }
    if require_seeds {
        if serve_seeds.is_empty() {
            return Err("serve-no-panic: no entry-point seeds found \
                 (Engine::serve / decode_step_batch / pub ExpertStore fns) — \
                 the analyzer would silently check nothing"
                .to_string());
        }
        if !(have_engine_serve && have_decode && have_store) {
            return Err(format!(
                "serve-no-panic: seed families missing (Engine::serve: {have_engine_serve}, \
                 decode_step_batch: {have_decode}, ExpertStore pub fns: {have_store}) — \
                 entry points moved without updating xtask/src/analyses.rs"
            ));
        }
    }

    let parent = graph.reach(&serve_seeds);

    // --- serve-no-panic + serve-unguarded-index --------------------------
    for (id, node) in graph.nodes.iter().enumerate() {
        if parent[id].is_none() {
            continue;
        }
        let prep = graph_files[node.file];
        let f = &prep.items.fns[node.item];
        if f.is_test {
            continue;
        }
        let toks = &prep.items.toks;
        let owned = |j: usize| graph.owner(node.file, j) == Some(node.item);
        let chain = || graph.chain(&items, &parent, id);

        // Panic-family ops.
        let mut flagged_lines: HashSet<u32> = HashSet::new();
        for j in f.body.clone() {
            if !owned(j) {
                continue;
            }
            let t = &toks[j];
            let mut hit: Option<String> = None;
            if t.kind == TokKind::Ident
                && PANIC_MACROS.contains(&t.text.as_str())
                && toks.get(j + 1).map(|n| n.is_punct("!")).unwrap_or(false)
            {
                hit = Some(format!("`{}!`", t.text));
            } else if t.is_punct(".")
                && toks.get(j + 1).map(|n| n.kind == TokKind::Ident).unwrap_or(false)
                && toks.get(j + 2).map(|n| n.is_punct("(")).unwrap_or(false)
            {
                let name = toks[j + 1].text.as_str();
                if name == "expect" {
                    hit = Some("`.expect(…)`".to_string());
                } else if name == "unwrap" && !is_poison_unwrap_tok(toks, j) {
                    hit = Some("`.unwrap()` (not a poisoned-lock unwrap)".to_string());
                }
            }
            if let Some(what) = hit {
                let line = t.line;
                if flagged_lines.insert(line) && !allowed(prep, "serve-no-panic", line) {
                    findings.push(Finding {
                        rel: prep.items.rel.clone(),
                        line: line as usize,
                        rule: "serve-no-panic",
                        msg: format!(
                            "{what} reachable from the serve entry points; chain: {}",
                            chain()
                        ),
                    });
                }
            }
        }

        // Unguarded indexing: one finding per fn, at the first site.
        if !body_has_bounds_guard(toks, f.body.clone()) {
            if let Some((line, recv)) = first_index_site(toks, f.body.clone(), &owned) {
                if !allowed(prep, "serve-unguarded-index", line) {
                    findings.push(Finding {
                        rel: prep.items.rel.clone(),
                        line: line as usize,
                        rule: "serve-unguarded-index",
                        msg: format!(
                            "`{recv}[…]` in a serve-reachable fn with no bounds guard \
                             (no assert/debug_assert/len/is_empty in `{}`); chain: {}",
                            f.name,
                            chain()
                        ),
                    });
                }
            }
        }
    }

    // --- no-fma-transitive -----------------------------------------------
    let mut fma_seeds: Vec<usize> = Vec::new();
    for (id, node) in graph.nodes.iter().enumerate() {
        let f = &items[node.file].fns[node.item];
        if !f.is_test && FMA_SEED_FILES.contains(&items[node.file].rel.as_str()) {
            fma_seeds.push(id);
        }
    }
    if require_seeds && fma_seeds.is_empty() {
        return Err("no-fma-transitive: kernel contract files have no functions — \
             FMA_SEED_FILES in xtask/src/analyses.rs is stale"
            .to_string());
    }
    let fma_parent = graph.reach(&fma_seeds);
    for (id, node) in graph.nodes.iter().enumerate() {
        if fma_parent[id].is_none() {
            continue;
        }
        let prep = graph_files[node.file];
        let f = &prep.items.fns[node.item];
        if f.is_test {
            continue;
        }
        let in_simd = prep.items.rel == "rust/src/tensor/simd.rs";
        for j in f.body.clone() {
            if graph.owner(node.file, j) != Some(node.item) {
                continue;
            }
            let t = &prep.items.toks[j];
            if t.kind == TokKind::Ident && is_fma_token(&t.text) {
                let line = t.line;
                // Only the pinned simd.rs region (which sets the no-fma
                // mask there) and explicit no-fma-transitive allows exempt.
                let exempt = allowed(prep, "no-fma-transitive", line)
                    || (in_simd && allowed(prep, "no-fma", line));
                if !exempt {
                    findings.push(Finding {
                        rel: prep.items.rel.clone(),
                        line: line as usize,
                        rule: "no-fma-transitive",
                        msg: format!(
                            "fused multiply-add `{}` reachable from the kernel contract \
                             region; chain: {}",
                            t.text,
                            graph.chain(&items, &fma_parent, id)
                        ),
                    });
                }
            }
        }
    }

    // --- float-hash-order (all non-test fns, reachable or not) -----------
    for prep in &graph_files {
        let hash_names = hash_typed_names(&prep.items.toks);
        for f in &prep.items.fns {
            if f.is_test {
                continue;
            }
            for (line, name) in
                float_accum_over_hash(&prep.items.toks, f.body.clone(), &hash_names)
            {
                if !allowed(prep, "float-hash-order", line) {
                    findings.push(Finding {
                        rel: prep.items.rel.clone(),
                        line: line as usize,
                        rule: "float-hash-order",
                        msg: format!(
                            "f32/f64 accumulation over `{name}` (HashMap/HashSet) iteration: \
                             hash order is nondeterministic and breaks the pinned operation \
                             DAG — iterate a sorted view instead"
                        ),
                    });
                }
            }
        }
    }

    // --- module-layering --------------------------------------------------
    if let Some(man) = manifest {
        let test_lines: Vec<Vec<bool>> =
            graph_files.iter().map(|p| p.sf.is_test.clone()).collect();
        let mg = ModuleGraph::build(&items, &test_lines);
        findings.extend(check_layering(&mg, man));
    }

    // Dedup (nested fns can attribute one line to two functions).
    findings.sort_by(|a, b| {
        (a.rel.as_str(), a.line, a.rule).cmp(&(b.rel.as_str(), b.line, b.rule))
    });
    findings.dedup_by(|a, b| a.rel == b.rel && a.line == b.line && a.rule == b.rule);
    Ok(findings)
}

fn allowed(prep: &Prepared, rule: &str, line: u32) -> bool {
    prep.allow
        .get(rule)
        .and_then(|v| v.get(line.saturating_sub(1) as usize))
        .copied()
        .unwrap_or(false)
}

fn is_fma_token(text: &str) -> bool {
    text == "mul_add" || text.contains("fmadd") || text.contains("vfma") || text.contains("fmla")
}

/// Token-level poison-unwrap check: `….lock().unwrap()` /
/// `….wait(…).unwrap()` / `….wait_timeout(…).unwrap()`. `toks[dot]` is
/// the `.` of `.unwrap(`; the receiver must be a call whose callee is one
/// of the poison-returning names. Works across lines (an improvement over
/// the old per-line check).
fn is_poison_unwrap_tok(toks: &[Tok], dot: usize) -> bool {
    if dot == 0 || !toks[dot - 1].is_punct(")") {
        return false;
    }
    let mut depth = 0i32;
    let mut k = dot - 1;
    loop {
        if toks[k].is_punct(")") {
            depth += 1;
        } else if toks[k].is_punct("(") {
            depth -= 1;
            if depth == 0 {
                break;
            }
        }
        if k == 0 {
            return false;
        }
        k -= 1;
    }
    k.checked_sub(1)
        .map(|m| {
            toks[m].kind == TokKind::Ident
                && matches!(toks[m].text.as_str(), "lock" | "wait" | "wait_timeout")
        })
        .unwrap_or(false)
}

/// Does the body contain any bounds-guard evidence: an assert-family
/// macro, or a `.len(` / `.is_empty(` call?
fn body_has_bounds_guard(toks: &[Tok], body: std::ops::Range<usize>) -> bool {
    for j in body {
        let t = &toks[j];
        if t.kind == TokKind::Ident
            && ASSERT_MACROS.contains(&t.text.as_str())
            && toks.get(j + 1).map(|n| n.is_punct("!")).unwrap_or(false)
        {
            return true;
        }
        if t.is_punct(".")
            && toks
                .get(j + 1)
                .map(|n| n.is_ident("len") || n.is_ident("is_empty"))
                .unwrap_or(false)
            && toks.get(j + 2).map(|n| n.is_punct("(")).unwrap_or(false)
        {
            return true;
        }
    }
    false
}

/// Identifier-receiver index sites (`recv[`, `x.field[`, `call()[`,
/// `arr[i][j]`), skipping macro brackets (`vec![`), attributes (`#[`),
/// array literals/types/patterns (previous token is punctuation), and
/// array literals directly after expression keywords (`return [a, b]`).
fn first_index_site(
    toks: &[Tok],
    body: std::ops::Range<usize>,
    owned: &dyn Fn(usize) -> bool,
) -> Option<(u32, String)> {
    const EXPR_KEYWORDS: &[&str] = &["return", "break", "else", "in", "match", "if", "while"];
    for j in body {
        if !owned(j) || !toks[j].is_punct("[") {
            continue;
        }
        let Some(k) = j.checked_sub(1) else {
            continue;
        };
        let prev = &toks[k];
        let ok = match prev.kind {
            TokKind::Ident => !EXPR_KEYWORDS.contains(&prev.text.as_str()),
            TokKind::Punct => prev.text == "]" || prev.text == ")",
            _ => false,
        };
        if !ok {
            continue;
        }
        // Name the receiver: walk back over a `a.b.c` chain to its head.
        let mut m = k;
        while m >= 2 && toks[m - 1].is_punct(".") && toks[m - 2].kind == TokKind::Ident {
            m -= 2;
        }
        let recv = if toks[m].kind == TokKind::Ident {
            toks[m].text.clone()
        } else {
            "expr".to_string()
        };
        return Some((toks[j].line, recv));
    }
    None
}

/// Names with a HashMap/HashSet type ascription or constructor assignment
/// anywhere in the file (fields, params, lets — an over-approximation in
/// the safe direction).
fn hash_typed_names(toks: &[Tok]) -> HashSet<String> {
    let mut out = HashSet::new();
    let is_hash = |t: &Tok| t.is_ident("HashMap") || t.is_ident("HashSet");
    for j in 0..toks.len() {
        if toks[j].kind != TokKind::Ident {
            continue;
        }
        let Some(next) = toks.get(j + 1) else {
            continue;
        };
        if next.is_punct(":") {
            // `name: … HashMap<…>` up to a terminator.
            for t in toks.iter().skip(j + 2).take(8) {
                if t.kind == TokKind::Punct
                    && matches!(t.text.as_str(), "," | ";" | ")" | "{" | "}" | "=")
                {
                    break;
                }
                if is_hash(t) {
                    out.insert(toks[j].text.clone());
                    break;
                }
            }
        } else if next.is_punct("=")
            && toks
                .get(j + 2)
                .map(|t| t.is_ident("HashMap") || t.is_ident("HashSet"))
                .unwrap_or(false)
        {
            out.insert(toks[j].text.clone());
        }
    }
    out
}

/// Float-typed names in a token range: `name: f32`, `name = 0.5`,
/// `name = -1.0f64`.
fn float_typed_names(toks: &[Tok], range: std::ops::Range<usize>) -> HashSet<String> {
    let mut out = HashSet::new();
    for j in range {
        if toks[j].kind != TokKind::Ident {
            continue;
        }
        let Some(next) = toks.get(j + 1) else {
            continue;
        };
        if next.is_punct(":") {
            for t in toks.iter().skip(j + 2).take(4) {
                if t.is_ident("f32") || t.is_ident("f64") {
                    out.insert(toks[j].text.clone());
                    break;
                }
                if t.kind == TokKind::Punct
                    && !matches!(t.text.as_str(), "&" | "<" | "::")
                    && t.text != "mut"
                {
                    break;
                }
            }
        } else if next.is_punct("=") {
            let mut k = j + 2;
            if toks.get(k).map(|t| t.is_punct("-")).unwrap_or(false) {
                k += 1;
            }
            if toks
                .get(k)
                .map(|t| t.kind == TokKind::Num && is_float_lit(&t.text))
                .unwrap_or(false)
            {
                out.insert(toks[j].text.clone());
            }
        }
    }
    out
}

fn is_float_lit(text: &str) -> bool {
    text.contains('.') || text.ends_with("f32") || text.ends_with("f64")
}

/// Find float accumulation inside hash-iterating loops (and
/// `.sum::<f32>()` chains on hash receivers) within one fn body.
/// Returns (line, hash name) per offense.
fn float_accum_over_hash(
    toks: &[Tok],
    body: std::ops::Range<usize>,
    hash_names: &HashSet<String>,
) -> Vec<(u32, String)> {
    let mut out = Vec::new();
    let floats = float_typed_names(toks, body.clone());
    let is_float_evidence = |t: &Tok| match t.kind {
        TokKind::Num => is_float_lit(&t.text),
        TokKind::Ident => {
            t.text == "f32" || t.text == "f64" || floats.contains(&t.text)
        }
        _ => false,
    };

    // `for pat in <hash expr> { … accum … }`
    let mut j = body.start;
    while j < body.end {
        if !toks[j].is_ident("for")
            || toks.get(j + 1).map(|t| t.is_punct("<")).unwrap_or(false)
        {
            j += 1;
            continue;
        }
        // Locate `in` at bracket depth 0, then the body `{`.
        let mut depth = 0i32;
        let mut k = j + 1;
        let mut in_at = None;
        while k < body.end {
            let t = &toks[k];
            match t.text.as_str() {
                "(" | "[" if t.kind == TokKind::Punct => depth += 1,
                ")" | "]" if t.kind == TokKind::Punct => depth -= 1,
                "in" if t.kind == TokKind::Ident && depth == 0 => {
                    in_at = Some(k);
                }
                "{" if t.kind == TokKind::Punct && depth == 0 => break,
                _ => {}
            }
            if in_at.is_some() {
                break;
            }
            k += 1;
        }
        let Some(in_at) = in_at else {
            j += 1;
            continue;
        };
        // Iterated expression: up to the loop brace.
        let mut depth = 0i32;
        let mut e = in_at + 1;
        let mut brace = None;
        while e < body.end {
            let t = &toks[e];
            match t.text.as_str() {
                "(" | "[" if t.kind == TokKind::Punct => depth += 1,
                ")" | "]" if t.kind == TokKind::Punct => depth -= 1,
                "{" if t.kind == TokKind::Punct && depth == 0 => {
                    brace = Some(e);
                    break;
                }
                _ => {}
            }
            e += 1;
        }
        let Some(brace) = brace else {
            j = in_at + 1;
            continue;
        };
        let hash_in_expr = toks[in_at + 1..brace].iter().find_map(|t| {
            (t.kind == TokKind::Ident
                && (hash_names.contains(&t.text)
                    || t.text == "HashMap"
                    || t.text == "HashSet"))
                .then(|| t.text.clone())
        });
        let loop_end = matching_brace(toks, brace).unwrap_or(body.end);
        if let Some(hname) = hash_in_expr {
            for a in brace..loop_end {
                let t = &toks[a];
                if t.kind == TokKind::Punct
                    && matches!(t.text.as_str(), "+=" | "-=" | "*=")
                {
                    // LHS ident directly before the op, or float evidence
                    // in the RHS up to `;`.
                    let lhs_float = a
                        .checked_sub(1)
                        .map(|p| {
                            toks[p].kind == TokKind::Ident && floats.contains(&toks[p].text)
                        })
                        .unwrap_or(false);
                    let rhs_float = toks[a + 1..loop_end]
                        .iter()
                        .take_while(|t| !t.is_punct(";"))
                        .any(|t| is_float_evidence(t));
                    if lhs_float || rhs_float {
                        out.push((t.line, hname.clone()));
                    }
                }
            }
        }
        j = brace + 1;
    }

    // `<hash>.iter().map(…).sum::<f32>()` chains.
    for j in body.clone() {
        if !toks[j].is_ident("sum") {
            continue;
        }
        let is_float_sum = toks.get(j + 1).map(|t| t.is_punct("::")).unwrap_or(false)
            && toks.get(j + 2).map(|t| t.is_punct("<")).unwrap_or(false)
            && toks
                .get(j + 3)
                .map(|t| t.is_ident("f32") || t.is_ident("f64"))
                .unwrap_or(false);
        if !is_float_sum {
            continue;
        }
        // Statement start: walk back to `;` / `{` / `}`.
        let mut s = j;
        while s > body.start {
            let t = &toks[s - 1];
            if t.kind == TokKind::Punct && matches!(t.text.as_str(), ";" | "{" | "}") {
                break;
            }
            s -= 1;
        }
        if let Some(h) = toks[s..j]
            .iter()
            .find(|t| t.kind == TokKind::Ident && hash_names.contains(&t.text))
        {
            out.push((toks[j].line, h.text.clone()));
        }
    }
    out
}

/// Index just past the brace matching `toks[open]`.
fn matching_brace(toks: &[Tok], open: usize) -> Option<usize> {
    let mut depth = 0i32;
    for (j, t) in toks.iter().enumerate().skip(open) {
        if t.is_punct("{") {
            depth += 1;
        } else if t.is_punct("}") {
            depth -= 1;
            if depth == 0 {
                return Some(j + 1);
            }
        }
    }
    None
}

// ---------------------------------------------------------------------
// Layering manifest
// ---------------------------------------------------------------------

/// Parsed `layering.toml`: module → allowed dependency set (or `*`).
pub struct Manifest {
    pub rel: String,
    /// module → (allowed targets or None for `*`, 1-based line).
    pub entries: BTreeMap<String, (Option<Vec<String>>, u32)>,
}

/// Parse the layering manifest (a deliberate TOML subset: `# comments`,
/// `name = []`, `name = ["a", "b"]`, `name = "*"`).
pub fn parse_manifest(rel: &str, text: &str) -> Result<Manifest, String> {
    let mut entries = BTreeMap::new();
    for (i, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let Some((name, value)) = line.split_once('=') else {
            return Err(format!("{rel}:{}: expected `module = [...]`", i + 1));
        };
        let name = name.trim().to_string();
        let value = value.trim();
        let allowed = if value == "\"*\"" {
            None
        } else if value.starts_with('[') && value.ends_with(']') {
            let inner = &value[1..value.len() - 1];
            let mut list = Vec::new();
            for part in inner.split(',') {
                let part = part.trim();
                if part.is_empty() {
                    continue;
                }
                let part = part.trim_matches('"');
                if part.is_empty() || part.contains(|c: char| !c.is_ascii_alphanumeric() && c != '_')
                {
                    return Err(format!("{rel}:{}: bad module name `{part}`", i + 1));
                }
                list.push(part.to_string());
            }
            Some(list)
        } else {
            return Err(format!("{rel}:{}: value must be a list or \"*\"", i + 1));
        };
        if entries.insert(name.clone(), (allowed, (i + 1) as u32)).is_some() {
            return Err(format!("{rel}:{}: duplicate entry for `{name}`", i + 1));
        }
    }
    Ok(Manifest { rel: rel.to_string(), entries })
}

/// Check the module graph against the manifest: unknown manifest entries,
/// uncovered modules, disallowed edges, cycles.
fn check_layering(mg: &ModuleGraph, man: &Manifest) -> Vec<Finding> {
    let mut findings = Vec::new();
    for (name, (_, line)) in &man.entries {
        if !mg.modules.contains(name) {
            findings.push(Finding {
                rel: man.rel.clone(),
                line: *line as usize,
                rule: "module-layering",
                msg: format!("manifest entry `{name}` matches no module under rust/src"),
            });
        }
    }
    for m in &mg.modules {
        if !man.entries.contains_key(m) {
            findings.push(Finding {
                rel: man.rel.clone(),
                line: 1,
                rule: "module-layering",
                msg: format!("module `{m}` has no entry in the layering manifest"),
            });
        }
    }
    for (from, tos) in &mg.edges {
        let Some((allowed, _)) = man.entries.get(from) else {
            continue; // already reported as uncovered
        };
        let Some(allowed) = allowed else {
            continue; // `*`
        };
        for (to, (rel, line)) in tos {
            if !allowed.contains(to) {
                findings.push(Finding {
                    rel: rel.clone(),
                    line: *line as usize,
                    rule: "module-layering",
                    msg: format!(
                        "module `{from}` must not depend on `{to}` \
                         (edge not allowed by {}); first use here",
                        man.rel
                    ),
                });
            }
        }
    }
    if let Some(cycle) = mg.find_cycle() {
        let head = cycle.first().cloned().unwrap_or_default();
        let evidence = cycle
            .first()
            .zip(cycle.get(1))
            .and_then(|(a, b)| mg.edges.get(a).and_then(|e| e.get(b)).cloned());
        let (rel, line) =
            evidence.unwrap_or_else(|| (man.rel.clone(), 1));
        findings.push(Finding {
            rel,
            line: line as usize,
            rule: "module-layering",
            msg: format!(
                "module dependency cycle: {} (layering must be a DAG); \
                 first edge of the cycle from `{head}` shown",
                cycle.join(" → ")
            ),
        });
    }
    findings
}
