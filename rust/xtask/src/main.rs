//! `xtask` — repo automation, currently the invariant linter.
//!
//! Run from anywhere in the workspace:
//!
//! ```text
//! cargo run -p xtask -- lint                  # lint the repo (exit 1 on findings)
//! cargo run -p xtask -- lint --root P         # lint an explicit checkout
//! cargo run -p xtask -- lint --sarif out.sarif  # also write SARIF 2.1.0
//! cargo run -p xtask -- lint --budget-ms 5000   # fail if linting takes longer
//! cargo run -p xtask -- rules                 # list rule ids + descriptions
//! ```
//!
//! The crate is std-only (like the vendored `anyhow` shim) so it builds
//! with no registry access. The pipeline: `scan.rs` strips comments and
//! string/char literals per line, `lexer.rs` tokenizes, `items.rs`
//! extracts fns/impls/uses, `graph.rs` builds the call graph and module
//! graph, and `rules.rs`/`analyses.rs` run the line rules and the
//! graph-transitive analyses over them. The README's "Static analysis &
//! invariants" section is the user-facing summary.

mod analyses;
mod graph;
mod items;
mod lexer;
mod rules;
mod sarif;
mod scan;

use std::path::PathBuf;
use std::process::ExitCode;
use std::time::Instant;

const USAGE: &str = "usage: cargo run -p xtask -- <command>\n\
commands:\n  \
  lint [--root <path>] [--sarif <file>] [--budget-ms <n>]\n                         \
lint the source tree against the repo invariants\n  \
  rules                  list lint rule ids and what they protect";

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("lint") => lint_cmd(&args[1..]),
        Some("rules") => {
            for (id, desc) in rules::RULES {
                println!("{id:22} {desc}");
            }
            ExitCode::SUCCESS
        }
        _ => {
            eprintln!("{USAGE}");
            ExitCode::from(2)
        }
    }
}

fn lint_cmd(args: &[String]) -> ExitCode {
    let mut root: Option<PathBuf> = None;
    let mut sarif_out: Option<PathBuf> = None;
    let mut budget_ms: Option<u128> = None;
    let mut i = 0usize;
    while i < args.len() {
        match args[i].as_str() {
            "--root" => {
                i += 1;
                match args.get(i) {
                    Some(p) => root = Some(PathBuf::from(p)),
                    None => {
                        eprintln!("--root needs a path\n{USAGE}");
                        return ExitCode::from(2);
                    }
                }
            }
            "--sarif" => {
                i += 1;
                match args.get(i) {
                    Some(p) => sarif_out = Some(PathBuf::from(p)),
                    None => {
                        eprintln!("--sarif needs an output path\n{USAGE}");
                        return ExitCode::from(2);
                    }
                }
            }
            "--budget-ms" => {
                i += 1;
                match args.get(i).and_then(|s| s.parse::<u128>().ok()) {
                    Some(ms) => budget_ms = Some(ms),
                    None => {
                        eprintln!("--budget-ms needs a number of milliseconds\n{USAGE}");
                        return ExitCode::from(2);
                    }
                }
            }
            other => {
                eprintln!("unknown argument `{other}`\n{USAGE}");
                return ExitCode::from(2);
            }
        }
        i += 1;
    }
    let root = root.unwrap_or_else(default_root);
    let started = Instant::now();
    let report = match rules::lint_tree(&root) {
        Err(e) => {
            eprintln!("xtask lint: {e}");
            return ExitCode::from(2);
        }
        Ok(r) => r,
    };
    let elapsed_ms = started.elapsed().as_millis();
    // SARIF is written even when clean: CI uploads the artifact and
    // validates it against the 2.1.0 schema on every run.
    if let Some(path) = &sarif_out {
        let doc = sarif::render(&report.findings);
        if let Err(e) = std::fs::write(path, doc) {
            eprintln!("xtask lint: write {}: {e}", path.display());
            return ExitCode::from(2);
        }
        println!("xtask lint: wrote SARIF to {}", path.display());
    }
    let over_budget = matches!(budget_ms, Some(ms) if elapsed_ms > ms);
    if over_budget {
        eprintln!(
            "xtask lint: took {elapsed_ms}ms, over the {}ms budget — the \
             analyzer must stay fast enough to run as the first tier-1 step",
            budget_ms.unwrap_or(0)
        );
    }
    if report.findings.is_empty() {
        println!(
            "xtask lint: clean ({} files, {} rules, {elapsed_ms}ms)",
            report.files_checked,
            rules::RULES.len()
        );
        if over_budget {
            return ExitCode::FAILURE;
        }
        ExitCode::SUCCESS
    } else {
        for f in &report.findings {
            println!("{}:{}: [{}] {}", f.rel, f.line, f.rule, f.msg);
        }
        eprintln!(
            "xtask lint: {} violation(s) in {} files ({elapsed_ms}ms)",
            report.findings.len(),
            report.files_checked
        );
        ExitCode::FAILURE
    }
}

/// The repo root: two levels above this crate's manifest (`rust/xtask`),
/// falling back to the current directory for a prebuilt binary run
/// outside cargo.
fn default_root() -> PathBuf {
    if let Ok(md) = std::env::var("CARGO_MANIFEST_DIR") {
        let p = PathBuf::from(md);
        if let Some(r) = p.parent().and_then(|q| q.parent()) {
            return r.to_path_buf();
        }
    }
    PathBuf::from(".")
}
