//! `xtask` — repo automation, currently the invariant linter.
//!
//! Run from anywhere in the workspace:
//!
//! ```text
//! cargo run -p xtask -- lint            # lint the repo (exit 1 on findings)
//! cargo run -p xtask -- lint --root P   # lint an explicit checkout
//! cargo run -p xtask -- rules           # list rule ids + descriptions
//! ```
//!
//! The crate is std-only (like the vendored `anyhow` shim) so it builds
//! with no registry access. See `rules.rs` for what each invariant
//! protects and `scan.rs` for how source is tokenized; the README's
//! "Static analysis & invariants" section is the user-facing summary.

mod rules;
mod scan;

use std::path::PathBuf;
use std::process::ExitCode;

const USAGE: &str = "usage: cargo run -p xtask -- <command>\n\
commands:\n  \
  lint [--root <path>]   lint the source tree against the repo invariants\n  \
  rules                  list lint rule ids and what they protect";

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("lint") => lint_cmd(&args[1..]),
        Some("rules") => {
            for (id, desc) in rules::RULES {
                println!("{id:22} {desc}");
            }
            ExitCode::SUCCESS
        }
        _ => {
            eprintln!("{USAGE}");
            ExitCode::from(2)
        }
    }
}

fn lint_cmd(args: &[String]) -> ExitCode {
    let mut root: Option<PathBuf> = None;
    let mut i = 0usize;
    while i < args.len() {
        match args[i].as_str() {
            "--root" => {
                i += 1;
                match args.get(i) {
                    Some(p) => root = Some(PathBuf::from(p)),
                    None => {
                        eprintln!("--root needs a path\n{USAGE}");
                        return ExitCode::from(2);
                    }
                }
            }
            other => {
                eprintln!("unknown argument `{other}`\n{USAGE}");
                return ExitCode::from(2);
            }
        }
        i += 1;
    }
    let root = root.unwrap_or_else(default_root);
    match rules::lint_tree(&root) {
        Err(e) => {
            eprintln!("xtask lint: {e}");
            ExitCode::from(2)
        }
        Ok(report) if report.findings.is_empty() => {
            println!(
                "xtask lint: clean ({} files, {} rules)",
                report.files_checked,
                rules::RULES.len()
            );
            ExitCode::SUCCESS
        }
        Ok(report) => {
            for f in &report.findings {
                println!("{}:{}: [{}] {}", f.rel, f.line, f.rule, f.msg);
            }
            eprintln!(
                "xtask lint: {} violation(s) in {} files",
                report.findings.len(),
                report.files_checked
            );
            ExitCode::FAILURE
        }
    }
}

/// The repo root: two levels above this crate's manifest (`rust/xtask`),
/// falling back to the current directory for a prebuilt binary run
/// outside cargo.
fn default_root() -> PathBuf {
    if let Ok(md) = std::env::var("CARGO_MANIFEST_DIR") {
        let p = PathBuf::from(md);
        if let Some(r) = p.parent().and_then(|q| q.parent()) {
            return r.to_path_buf();
        }
    }
    PathBuf::from(".")
}
