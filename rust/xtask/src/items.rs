//! Item extraction — fn / impl / mod / use spans per file.
//!
//! Consumes the token stream from [`crate::lexer`] and produces the symbol
//! inventory the graphs are built from: every function with its enclosing
//! `impl` type and inline-module path, its body as a token range, its
//! visibility, and whether it is test code; plus every `use` declaration
//! with brace groups expanded into leaf paths.
//!
//! The extractor is a single pass with an explicit scope stack (`mod` /
//! `impl`+`trait` / `fn` / plain block). It is *not* a parser — it only
//! tracks the brace structure and the handful of keywords that delimit
//! items, which is exactly enough to answer "which function does this
//! token belong to" and "which modules does this file import from". The
//! known simplifications (same spirit as `scan.rs`): out-of-line
//! `mod x;` declarations are ignored (module structure comes from file
//! paths), and `#[cfg(test)]` detection matches the literal `cfg(test…)` /
//! `#[test]` shapes used in this repo.

use crate::lexer::{tokenize, Tok, TokKind};

/// One extracted function (or default trait method).
pub struct FnItem {
    pub name: String,
    /// Enclosing `impl`/`trait` type name, if any (`ExpertStore`, `Engine`).
    pub impl_type: Option<String>,
    /// Module path: file-derived segments plus inline `mod` names.
    pub module: Vec<String>,
    pub is_pub: bool,
    pub is_test: bool,
    /// 1-based line of the `fn` keyword.
    pub line: u32,
    /// Token range of the body, including both braces. Empty for
    /// body-less declarations (which are not recorded).
    pub body: std::ops::Range<usize>,
}

/// One leaf path of a `use` declaration (`use a::{b, c::d}` yields two).
pub struct UseDecl {
    /// 1-based line of the `use` keyword.
    pub line: u32,
    /// Path segments, `*` for globs; `as` aliases are dropped.
    pub segments: Vec<String>,
    pub is_test: bool,
}

/// The symbol inventory of one file.
pub struct FileItems {
    pub rel: String,
    pub toks: Vec<Tok>,
    pub fns: Vec<FnItem>,
    pub uses: Vec<UseDecl>,
}

/// Module path for a repo-relative file: `rust/src/tensor/ops.rs` →
/// `[tensor, ops]`, `rust/src/report/mod.rs` → `[report]`,
/// `rust/src/lib.rs` → `[]`.
pub fn file_module(rel: &str) -> Vec<String> {
    let Some(p) = rel.strip_prefix("rust/src/") else {
        return Vec::new();
    };
    let p = p.strip_suffix(".rs").unwrap_or(p);
    let mut segs: Vec<String> = p.split('/').map(|s| s.to_string()).collect();
    if segs.last().map(String::as_str) == Some("mod") {
        segs.pop();
    }
    if segs.last().map(String::as_str) == Some("lib") {
        segs.pop();
    }
    segs
}

enum ScopeKind {
    Mod(String),
    /// `impl`/`trait` block with the resolved type name.
    Holder(Option<String>),
    Fn(usize),
    Block,
}

struct Scope {
    kind: ScopeKind,
    test: bool,
}

/// Extract items from one file's source.
pub fn extract(rel: &str, text: &str) -> FileItems {
    let toks = tokenize(text);
    let file_is_test = rel.starts_with("rust/tests/") || rel.starts_with("rust/benches/");
    let base_module = file_module(rel);
    let mut fns: Vec<FnItem> = Vec::new();
    let mut uses: Vec<UseDecl> = Vec::new();
    let mut scopes: Vec<Scope> = Vec::new();
    let mut pending_test = false;
    let n = toks.len();
    let mut i = 0usize;

    let cur_test = |scopes: &[Scope], pending: bool| -> bool {
        file_is_test || pending || scopes.iter().any(|s| s.test)
    };
    let cur_holder = |scopes: &[Scope]| -> Option<String> {
        scopes.iter().rev().find_map(|s| match &s.kind {
            ScopeKind::Holder(t) => Some(t.clone()),
            _ => None,
        })
    };
    let cur_module = |scopes: &[Scope], base: &[String]| -> Vec<String> {
        let mut m = base.to_vec();
        for s in scopes {
            if let ScopeKind::Mod(name) = &s.kind {
                m.push(name.clone());
            }
        }
        m
    };

    while i < n {
        let t = &toks[i];
        match t.kind {
            TokKind::Punct if t.text == "#" => {
                // Attribute: `#[…]` arms the test flag when it is a
                // `#[test]` / `#[cfg(test…)]` shape; `#![…]` never does.
                let inner = toks.get(i + 1).map(|t| t.is_punct("!")).unwrap_or(false);
                let open = i + 1 + usize::from(inner);
                if toks.get(open).map(|t| t.is_punct("[")).unwrap_or(false) {
                    let end = skip_balanced(&toks, open, "[", "]");
                    if !inner && attr_is_test(&toks[open + 1..end.saturating_sub(1)]) {
                        pending_test = true;
                    }
                    i = end;
                } else {
                    i += 1;
                }
            }
            TokKind::Ident if t.text == "mod" => {
                let name =
                    toks.get(i + 1).filter(|t| t.kind == TokKind::Ident).map(|t| t.text.clone());
                // `mod name {` opens an inline module; `mod name;` is
                // out-of-line and contributes nothing here.
                if let (Some(name), Some(br)) = (name, toks.get(i + 2)) {
                    if br.is_punct("{") {
                        scopes.push(Scope {
                            kind: ScopeKind::Mod(name),
                            test: cur_test(&scopes, pending_test),
                        });
                        pending_test = false;
                        i += 3;
                        continue;
                    }
                }
                pending_test = false;
                i += 1;
            }
            TokKind::Ident if t.text == "impl" || t.text == "trait" => {
                let (ty, body_open) = parse_holder_header(&toks, i);
                match body_open {
                    Some(open) => {
                        scopes.push(Scope {
                            kind: ScopeKind::Holder(ty),
                            test: cur_test(&scopes, pending_test),
                        });
                        pending_test = false;
                        i = open + 1;
                    }
                    None => {
                        pending_test = false;
                        i += 1;
                    }
                }
            }
            TokKind::Ident if t.text == "fn" => {
                let name = toks
                    .get(i + 1)
                    .filter(|t| t.kind == TokKind::Ident)
                    .map(|t| t.text.clone())
                    .unwrap_or_default();
                let is_pub = looks_pub(&toks, i);
                match find_fn_body(&toks, i + 2) {
                    Some(open) => {
                        let idx = fns.len();
                        fns.push(FnItem {
                            name,
                            impl_type: cur_holder(&scopes),
                            module: cur_module(&scopes, &base_module),
                            is_pub,
                            is_test: cur_test(&scopes, pending_test),
                            line: t.line,
                            body: open..open, // end patched at the closing brace
                        });
                        scopes.push(Scope {
                            kind: ScopeKind::Fn(idx),
                            test: cur_test(&scopes, pending_test),
                        });
                        pending_test = false;
                        i = open + 1;
                    }
                    None => {
                        // Declaration without a body (trait signature).
                        pending_test = false;
                        i += 1;
                    }
                }
            }
            TokKind::Ident if t.text == "use" => {
                let test = cur_test(&scopes, pending_test);
                let (decls, next) = parse_use(&toks, i, test);
                uses.extend(decls);
                pending_test = false;
                i = next;
            }
            TokKind::Punct if t.text == "{" => {
                scopes.push(Scope { kind: ScopeKind::Block, test: cur_test(&scopes, false) });
                i += 1;
            }
            TokKind::Punct if t.text == "}" => {
                if let Some(s) = scopes.pop() {
                    if let ScopeKind::Fn(idx) = s.kind {
                        let start = fns[idx].body.start;
                        fns[idx].body = start..i + 1;
                    }
                }
                i += 1;
            }
            _ => i += 1,
        }
    }
    // Unterminated scopes (truncated input): close fn bodies at EOF.
    while let Some(s) = scopes.pop() {
        if let ScopeKind::Fn(idx) = s.kind {
            let start = fns[idx].body.start;
            fns[idx].body = start..n;
        }
    }
    FileItems { rel: rel.to_string(), toks, fns, uses }
}

/// Does the attribute token body mark test code? Matches `test` alone
/// (`#[test]`, `#[tokio::test]`-style suffixes are not used here) and any
/// `cfg(… test …)` shape except `cfg(not(test))`.
fn attr_is_test(body: &[Tok]) -> bool {
    if body.len() == 1 && body[0].is_ident("test") {
        return true;
    }
    if !body.first().map(|t| t.is_ident("cfg")).unwrap_or(false) {
        return false;
    }
    let mut not_depth: i32 = -1;
    let mut depth: i32 = 0;
    for (k, t) in body.iter().enumerate() {
        match t.kind {
            TokKind::Punct if t.text == "(" => depth += 1,
            TokKind::Punct if t.text == ")" => {
                depth -= 1;
                if not_depth >= 0 && depth < not_depth {
                    not_depth = -1;
                }
            }
            TokKind::Ident if t.text == "not" => {
                if body.get(k + 1).map(|t| t.is_punct("(")).unwrap_or(false) {
                    not_depth = depth;
                }
            }
            TokKind::Ident if t.text == "test" && not_depth < 0 => return true,
            _ => {}
        }
    }
    false
}

/// Skip a balanced bracket group starting at `open` (which holds `open_p`);
/// returns the index just past the matching close.
fn skip_balanced(toks: &[Tok], open: usize, open_p: &str, close_p: &str) -> usize {
    let mut depth = 0i32;
    let mut i = open;
    while i < toks.len() {
        if toks[i].is_punct(open_p) {
            depth += 1;
        } else if toks[i].is_punct(close_p) {
            depth -= 1;
            if depth == 0 {
                return i + 1;
            }
        }
        i += 1;
    }
    toks.len()
}

/// Parse an `impl`/`trait` header starting at the keyword; returns the
/// resolved type name (for `impl Trait for Type`, the `Type`) and the
/// index of the body `{` (None for `impl Trait for Type;`-style or EOF).
fn parse_holder_header(toks: &[Tok], kw: usize) -> (Option<String>, Option<usize>) {
    let n = toks.len();
    let mut i = kw + 1;
    // Skip generic parameters, balancing shifts (`>>` closes two).
    if toks.get(i).map(|t| t.is_punct("<")).unwrap_or(false) {
        let mut depth = 0i32;
        while i < n {
            match toks[i].text.as_str() {
                "<" if toks[i].kind == TokKind::Punct => depth += 1,
                "<<" if toks[i].kind == TokKind::Punct => depth += 2,
                ">" if toks[i].kind == TokKind::Punct => depth -= 1,
                ">>" if toks[i].kind == TokKind::Punct => depth -= 2,
                _ => {}
            }
            i += 1;
            if depth <= 0 {
                break;
            }
        }
    }
    // Collect the subject tokens; `for` (not HRTB `for<`) switches to the
    // implementing type, `where` ends the subject.
    let mut subject: Vec<&Tok> = Vec::new();
    while i < n {
        let t = &toks[i];
        if t.is_punct("{") {
            return (type_name(&subject), Some(i));
        }
        if t.is_punct(";") {
            return (type_name(&subject), None);
        }
        if t.is_ident("for") && !toks.get(i + 1).map(|t| t.is_punct("<")).unwrap_or(false) {
            subject.clear();
            i += 1;
            continue;
        }
        if t.is_ident("where") {
            // Skip to the body brace.
            while i < n && !toks[i].is_punct("{") {
                i += 1;
            }
            continue;
        }
        subject.push(t);
        i += 1;
    }
    (type_name(&subject), None)
}

/// Type name from a subject token list: the identifier before the first
/// `<`, or the last identifier (`crate::model::Model` → `Model`).
fn type_name(subject: &[&Tok]) -> Option<String> {
    let mut last: Option<&str> = None;
    for t in subject {
        if t.kind == TokKind::Punct && (t.text == "<" || t.text == "<<") {
            return last.map(|s| s.to_string());
        }
        if t.kind == TokKind::Ident {
            last = Some(&t.text);
        }
    }
    last.map(|s| s.to_string())
}

/// Find the body `{` of a fn whose parameter list starts at/after `from`;
/// None when the signature ends in `;`. Braces can only open the body once
/// parens/brackets are balanced (no brace-bearing const expressions appear
/// in signatures in this tree).
fn find_fn_body(toks: &[Tok], from: usize) -> Option<usize> {
    let mut depth = 0i32;
    let mut i = from;
    while i < toks.len() {
        let t = &toks[i];
        if t.kind == TokKind::Punct {
            match t.text.as_str() {
                "(" | "[" => depth += 1,
                ")" | "]" => depth -= 1,
                "{" if depth == 0 => return Some(i),
                ";" if depth == 0 => return None,
                _ => {}
            }
        }
        i += 1;
    }
    None
}

/// Was the `fn` at `kw` preceded by `pub` within its qualifier run
/// (`pub`, `pub(crate)`, `pub unsafe fn`, …)?
fn looks_pub(toks: &[Tok], kw: usize) -> bool {
    let mut j = kw;
    while j > 0 {
        let t = &toks[j - 1];
        let qualifier = match t.kind {
            TokKind::Ident => matches!(
                t.text.as_str(),
                "pub" | "crate" | "super" | "self" | "in" | "unsafe" | "const" | "async" | "extern"
            ),
            TokKind::Str => true, // extern "C"
            TokKind::Punct => t.text == "(" || t.text == ")",
            _ => false,
        };
        if !qualifier {
            return false;
        }
        if t.is_ident("pub") {
            return true;
        }
        j -= 1;
    }
    false
}

/// Parse a `use` declaration at `kw`; returns the expanded leaf decls and
/// the index just past the terminating `;`.
fn parse_use(toks: &[Tok], kw: usize, is_test: bool) -> (Vec<UseDecl>, usize) {
    let line = toks[kw].line;
    let n = toks.len();
    let mut end = kw + 1;
    while end < n && !toks[end].is_punct(";") {
        end += 1;
    }
    let mut out = Vec::new();
    expand_use_tree(&toks[kw + 1..end], line, is_test, &mut Vec::new(), &mut out);
    (out, (end + 1).min(n))
}

/// Recursively expand a use tree (`a::{b, c::d}, e` …) into leaf paths.
fn expand_use_tree(
    toks: &[Tok],
    line: u32,
    is_test: bool,
    prefix: &mut Vec<String>,
    out: &mut Vec<UseDecl>,
) {
    let n = toks.len();
    let mut i = 0usize;
    let mut segs: Vec<String> = Vec::new();
    while i <= n {
        let at_end = i == n;
        let t = toks.get(i);
        if at_end || t.map(|t| t.is_punct(",")).unwrap_or(false) {
            if !segs.is_empty() {
                let mut full = prefix.clone();
                full.append(&mut segs);
                out.push(UseDecl { line, segments: full, is_test });
            }
            i += 1;
            continue;
        }
        let t = t.expect("bounds checked");
        match t.kind {
            TokKind::Ident if t.text == "as" => {
                // `x as y`: keep the path, drop the alias ident.
                i += 2;
            }
            TokKind::Ident => {
                segs.push(t.text.clone());
                i += 1;
            }
            TokKind::Punct if t.text == "*" => {
                segs.push("*".to_string());
                i += 1;
            }
            TokKind::Punct if t.text == "{" => {
                let close = skip_balanced(toks, i, "{", "}");
                let mut full = prefix.clone();
                full.extend(segs.drain(..));
                expand_use_tree(&toks[i + 1..close.saturating_sub(1)], line, is_test, &mut full, out);
                i = close;
                // A brace group ends this branch; skip to the next comma.
                while i < n && !toks[i].is_punct(",") {
                    i += 1;
                }
            }
            _ => i += 1, // `::` and stray tokens
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn file_module_paths() {
        assert_eq!(file_module("rust/src/tensor/ops.rs"), vec!["tensor", "ops"]);
        assert_eq!(file_module("rust/src/report/mod.rs"), vec!["report"]);
        assert!(file_module("rust/src/lib.rs").is_empty());
        assert_eq!(file_module("rust/src/main.rs"), vec!["main"]);
    }

    #[test]
    fn extracts_fns_with_impl_and_module() {
        let src = r#"
pub struct Engine;
impl Engine {
    pub fn serve(&self) { helper(); }
    fn private(&self) {}
}
mod inner {
    pub fn nested() {}
}
fn free() {}
"#;
        let fi = extract("rust/src/serve/engine.rs", src);
        let names: Vec<(String, Option<String>, bool)> =
            fi.fns.iter().map(|f| (f.name.clone(), f.impl_type.clone(), f.is_pub)).collect();
        assert_eq!(names[0], ("serve".into(), Some("Engine".into()), true));
        assert_eq!(names[1], ("private".into(), Some("Engine".into()), false));
        assert_eq!(names[2], ("nested".into(), None, true));
        assert_eq!(fi.fns[2].module, vec!["serve", "engine", "inner"]);
        assert_eq!(names[3], ("free".into(), None, false));
        assert!(fi.fns[3].module == vec!["serve", "engine"]);
    }

    #[test]
    fn impl_trait_for_type_resolves_to_type() {
        let src = "impl<T: Clone> std::fmt::Debug for Wrapper<T> where T: Copy { fn fmt(&self) {} }";
        let fi = extract("rust/src/x.rs", src);
        assert_eq!(fi.fns[0].impl_type.as_deref(), Some("Wrapper"));
    }

    #[test]
    fn trait_default_methods_and_sigs() {
        let src = "trait Backend { fn run(&self); fn name(&self) -> &str { helper() } }";
        let fi = extract("rust/src/x.rs", src);
        // Only the default method (with a body) is recorded.
        assert_eq!(fi.fns.len(), 1);
        assert_eq!(fi.fns[0].name, "name");
        assert_eq!(fi.fns[0].impl_type.as_deref(), Some("Backend"));
    }

    #[test]
    fn cfg_test_marks_fns() {
        let src = "fn prod() {}\n#[cfg(test)]\nmod tests {\n    fn t() { prod(); }\n}\n#[test]\nfn unit() {}\nfn prod2() {}";
        let fi = extract("rust/src/x.rs", src);
        let flags: Vec<(String, bool)> =
            fi.fns.iter().map(|f| (f.name.clone(), f.is_test)).collect();
        assert_eq!(
            flags,
            vec![
                ("prod".into(), false),
                ("t".into(), true),
                ("unit".into(), true),
                ("prod2".into(), false)
            ]
        );
    }

    #[test]
    fn cfg_not_test_is_not_test() {
        let src = "#[cfg(not(test))]\nfn prod() {}";
        let fi = extract("rust/src/x.rs", src);
        assert!(!fi.fns[0].is_test);
    }

    #[test]
    fn fn_body_ranges_cover_calls() {
        let src = "fn a() { one(); }\nfn b() { two(); }";
        let fi = extract("rust/src/x.rs", src);
        let body_a: Vec<&str> =
            fi.toks[fi.fns[0].body.clone()].iter().map(|t| t.text.as_str()).collect();
        assert!(body_a.contains(&"one"));
        assert!(!body_a.contains(&"two"));
    }

    #[test]
    fn use_trees_expand() {
        let src = "use crate::tensor::{ops, pool::ThreadPool};\nuse std::collections::HashMap as Map;\n#[cfg(test)]\nmod tests { use crate::model::ZooModel; }";
        let fi = extract("rust/src/x.rs", src);
        let paths: Vec<(Vec<String>, bool)> =
            fi.uses.iter().map(|u| (u.segments.clone(), u.is_test)).collect();
        assert_eq!(paths[0].0, vec!["crate", "tensor", "ops"]);
        assert_eq!(paths[1].0, vec!["crate", "tensor", "pool", "ThreadPool"]);
        assert_eq!(paths[2].0, vec!["std", "collections", "HashMap"]);
        assert!(!paths[2].1);
        assert_eq!(paths[3].0, vec!["crate", "model", "ZooModel"]);
        assert!(paths[3].1, "use inside cfg(test) module must be test-scoped");
    }

    #[test]
    fn tests_dir_is_all_test() {
        let fi = extract("rust/tests/integration.rs", "fn probe() {}");
        assert!(fi.fns[0].is_test);
    }
}
