//! Hand-written Rust lexer — the token layer under the symbol analyses.
//!
//! [`tokenize`] turns source text into a flat `Vec<Tok>` with 1-based line
//! numbers, skipping trivia (whitespace and comments). It exists so the
//! item extractor ([`crate::items`]) and the graphs built on top of it
//! ([`crate::graph`]) can reason about *symbols* — `fn` names, `impl`
//! targets, call sites, `use` paths — instead of raw lines, which is what
//! the PR 7 scanner was limited to.
//!
//! Lexical edge cases handled (and pinned by the property tests below —
//! the same generated token soups also exercise `scan.rs`, so the two
//! implementations cross-check each other):
//!
//! - nested block comments (`/* /* */ */` — Rust block comments nest);
//! - raw strings with any hash depth (`r"…"`, `r#"…"#`, `r##"…"##`) and
//!   their byte variants (`br#"…"#`), in which `\` is *not* an escape;
//! - string escapes (`"\""`, `"\\"`) and backslash-newline continuations;
//! - char/byte-char literals vs. lifetimes: `'"'`, `'/'`, `'\''`, `b'x'`
//!   are literals, `'static` / `'env` are lifetime tokens;
//! - raw identifiers (`r#match`) — lexed as identifiers, not raw strings.
//!
//! Deliberate simplifications (documented because the analyses tolerate
//! them): numeric literals with exponents (`1e-5`) lex as number + punct +
//! number, and float typedness is judged from the token text elsewhere.
//! Neither affects symbol extraction.

/// Token kind. `Punct` carries the joined spelling (`::`, `->`, `+=`, …).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword (`fn`, `impl`, `for`, names, …).
    Ident,
    /// Lifetime or loop label (`'a`, `'env`) — the text excludes the tick.
    Lifetime,
    /// String literal (`"…"`, `r#"…"#`, `b"…"`); text is the *content*.
    Str,
    /// Char or byte-char literal; text is the content (escapes verbatim).
    Char,
    /// Numeric literal (integers, simple floats, with suffixes).
    Num,
    /// Punctuation, possibly multi-char (`::`, `..=`, `+=`, `&&`, …).
    Punct,
}

/// One lexed token.
#[derive(Clone, Debug)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    /// 1-based source line of the token's first character.
    pub line: u32,
}

impl Tok {
    pub fn is(&self, kind: TokKind, text: &str) -> bool {
        self.kind == kind && self.text == text
    }
    pub fn is_punct(&self, text: &str) -> bool {
        self.is(TokKind::Punct, text)
    }
    pub fn is_ident(&self, text: &str) -> bool {
        self.is(TokKind::Ident, text)
    }
}

fn is_ident_start(c: char) -> bool {
    c.is_ascii_alphabetic() || c == '_' || !c.is_ascii()
}

fn is_ident_continue(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_' || !c.is_ascii()
}

/// Multi-char punctuation, longest-match-first. Joined spellings matter to
/// the analyses: `..` must not look like two method dots, `!=` must not
/// look like a macro bang, `+=` is how the float-accumulation lint finds
/// compound assignment.
const PUNCTS3: &[&str] = &["..=", "<<=", ">>=", "..."];
const PUNCTS2: &[&str] = &[
    "::", "->", "=>", "..", "==", "!=", "<=", ">=", "&&", "||", "<<", ">>", "+=", "-=", "*=",
    "/=", "%=", "^=", "&=", "|=",
];

/// Lex `src` into tokens, skipping comments and whitespace.
pub fn tokenize(src: &str) -> Vec<Tok> {
    let ch: Vec<char> = src.chars().collect();
    let n = ch.len();
    let mut toks: Vec<Tok> = Vec::new();
    let mut line: u32 = 1;
    let mut i = 0usize;

    // Count newlines in ch[from..to] into `line`.
    let bump = |line: &mut u32, ch: &[char], from: usize, to: usize| {
        *line += ch[from..to.min(ch.len())].iter().filter(|&&c| c == '\n').count() as u32;
    };

    while i < n {
        let c = ch[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // Line comment.
        if c == '/' && ch.get(i + 1) == Some(&'/') {
            while i < n && ch[i] != '\n' {
                i += 1;
            }
            continue;
        }
        // Block comment (nesting).
        if c == '/' && ch.get(i + 1) == Some(&'*') {
            let start = i;
            let mut depth = 1u32;
            i += 2;
            while i < n && depth > 0 {
                if ch[i] == '/' && ch.get(i + 1) == Some(&'*') {
                    depth += 1;
                    i += 2;
                } else if ch[i] == '*' && ch.get(i + 1) == Some(&'/') {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
            bump(&mut line, &ch, start, i);
            continue;
        }
        // Raw strings / byte strings / byte chars / raw identifiers.
        if c == 'r' || c == 'b' {
            if let Some((consumed, hashes, is_char)) = raw_or_byte_open(&ch, i) {
                let start = i;
                i += consumed;
                let content_start = i;
                if is_char {
                    let (content, next) = lex_char_body(&ch, i);
                    i = next;
                    toks.push(Tok { kind: TokKind::Char, text: content, line });
                    bump(&mut line, &ch, start, i);
                    continue;
                }
                // String body: raw (no escapes, closed by `"` + hashes) or
                // escaped (plain `b"…"`).
                let mut content = String::new();
                if let Some(h) = hashes {
                    while i < n {
                        if ch[i] == '"' && ends_hashes(&ch, i + 1, h) {
                            i += 1 + h as usize;
                            break;
                        }
                        content.push(ch[i]);
                        i += 1;
                    }
                } else {
                    let (s, next) = lex_str_body(&ch, i);
                    content = s;
                    i = next;
                }
                toks.push(Tok { kind: TokKind::Str, text: content, line });
                bump(&mut line, &ch, content_start.saturating_sub(1), i);
                continue;
            }
        }
        if c == '"' {
            let start = i;
            let (content, next) = lex_str_body(&ch, i + 1);
            i = next;
            toks.push(Tok { kind: TokKind::Str, text: content, line });
            bump(&mut line, &ch, start, i);
            continue;
        }
        // Tick: char literal or lifetime. Same disambiguation as scan.rs:
        // an escape (`'\…`) or a one-char body closed by `'` is a literal;
        // otherwise it is a lifetime/label tick.
        if c == '\'' {
            match ch.get(i + 1) {
                Some('\\') => {
                    let (content, next) = lex_char_body(&ch, i + 1);
                    i = next;
                    toks.push(Tok { kind: TokKind::Char, text: content, line });
                    continue;
                }
                Some(&x) if x != '\'' && ch.get(i + 2) == Some(&'\'') => {
                    toks.push(Tok { kind: TokKind::Char, text: x.to_string(), line });
                    i += 3;
                    continue;
                }
                _ => {
                    let mut j = i + 1;
                    let mut name = String::new();
                    while j < n && is_ident_continue(ch[j]) {
                        name.push(ch[j]);
                        j += 1;
                    }
                    toks.push(Tok { kind: TokKind::Lifetime, text: name, line });
                    i = j;
                    continue;
                }
            }
        }
        // Number: digits, then idents/underscores (suffixes, hex), and a
        // dot only when followed by a digit (so `0..n` stays a range).
        if c.is_ascii_digit() {
            let mut j = i;
            let mut text = String::new();
            while j < n {
                let d = ch[j];
                if d.is_ascii_alphanumeric() || d == '_' {
                    text.push(d);
                    j += 1;
                } else if d == '.' && ch.get(j + 1).map(|x| x.is_ascii_digit()).unwrap_or(false) {
                    text.push(d);
                    j += 1;
                } else {
                    break;
                }
            }
            toks.push(Tok { kind: TokKind::Num, text, line });
            i = j;
            continue;
        }
        // Identifier / keyword (including raw identifiers handled above).
        if is_ident_start(c) {
            let mut j = i;
            let mut text = String::new();
            while j < n && is_ident_continue(ch[j]) {
                text.push(ch[j]);
                j += 1;
            }
            toks.push(Tok { kind: TokKind::Ident, text, line });
            i = j;
            continue;
        }
        // Punctuation, longest match first.
        let rest3: String = ch[i..n.min(i + 3)].iter().collect();
        let rest2: String = ch[i..n.min(i + 2)].iter().collect();
        if PUNCTS3.contains(&rest3.as_str()) {
            toks.push(Tok { kind: TokKind::Punct, text: rest3, line });
            i += 3;
        } else if PUNCTS2.contains(&rest2.as_str()) {
            toks.push(Tok { kind: TokKind::Punct, text: rest2, line });
            i += 2;
        } else {
            toks.push(Tok { kind: TokKind::Punct, text: c.to_string(), line });
            i += 1;
        }
    }
    toks
}

/// `true` if `ch[j..]` starts with `hashes` copies of `#`.
fn ends_hashes(ch: &[char], j: usize, hashes: u32) -> bool {
    let h = hashes as usize;
    j + h <= ch.len() && ch[j..j + h].iter().all(|&c| c == '#')
}

/// If `ch[i..]` opens a raw/byte string or byte-char literal, return
/// (chars consumed through the opening delimiter, raw-hash count if raw,
/// whether it is a char literal). Mirrors `scan.rs::raw_or_byte_open`.
fn raw_or_byte_open(ch: &[char], i: usize) -> Option<(usize, Option<u32>, bool)> {
    let mut j = i;
    if ch[j] == 'b' {
        match ch.get(j + 1) {
            Some('"') => return Some((2, None, false)),
            Some('\'') => return Some((2, None, true)),
            Some('r') => j += 1,
            _ => return None,
        }
    }
    if ch[j] != 'r' {
        return None;
    }
    let mut hashes = 0u32;
    let mut k = j + 1;
    while ch.get(k) == Some(&'#') {
        hashes += 1;
        k += 1;
    }
    if ch.get(k) == Some(&'"') {
        Some((k + 1 - i, Some(hashes), false))
    } else {
        None
    }
}

/// Lex a (non-raw) string body starting *after* the opening `"`; returns
/// (content with escapes verbatim, index after the closing quote).
fn lex_str_body(ch: &[char], mut i: usize) -> (String, usize) {
    let n = ch.len();
    let mut out = String::new();
    while i < n {
        let c = ch[i];
        if c == '\\' {
            if let Some(&e) = ch.get(i + 1) {
                out.push(c);
                out.push(e);
                i += 2;
                continue;
            }
            i += 1;
        } else if c == '"' {
            i += 1;
            break;
        } else {
            out.push(c);
            i += 1;
        }
    }
    (out, i)
}

/// Lex a char-literal body starting *after* the opening `'`; returns
/// (content, index after the closing tick).
fn lex_char_body(ch: &[char], mut i: usize) -> (String, usize) {
    let n = ch.len();
    let mut out = String::new();
    while i < n {
        let c = ch[i];
        if c == '\\' {
            if let Some(&e) = ch.get(i + 1) {
                out.push(c);
                out.push(e);
                i += 2;
                continue;
            }
            i += 1;
        } else if c == '\'' {
            i += 1;
            break;
        } else {
            out.push(c);
            i += 1;
        }
    }
    (out, i)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scan::scan_source;

    fn idents(src: &str) -> Vec<String> {
        tokenize(src)
            .into_iter()
            .filter(|t| t.kind == TokKind::Ident)
            .map(|t| t.text)
            .collect()
    }

    #[test]
    fn basic_tokens_and_lines() {
        let toks = tokenize("fn foo() {\n    bar::baz(1);\n}");
        assert!(toks[0].is_ident("fn"));
        assert!(toks[1].is_ident("foo"));
        assert_eq!(toks[1].line, 1);
        let baz = toks.iter().find(|t| t.is_ident("baz")).unwrap();
        assert_eq!(baz.line, 2);
        assert!(toks.iter().any(|t| t.is_punct("::")));
    }

    #[test]
    fn raw_strings_do_not_leak_tokens() {
        let src = "let s = r#\"unsafe { mul_add } \"quoted\" \"#; call();";
        let ids = idents(src);
        assert!(!ids.contains(&"unsafe".to_string()));
        assert!(!ids.contains(&"mul_add".to_string()));
        assert!(ids.contains(&"call".to_string()));
        let s = tokenize(src).into_iter().find(|t| t.kind == TokKind::Str).unwrap();
        assert!(s.text.contains("mul_add"));
    }

    #[test]
    fn deep_hash_raw_strings() {
        let src = "let s = r##\"inner \"# quote\"##; after();";
        let ids = idents(src);
        assert!(!ids.contains(&"inner".to_string()));
        assert!(ids.contains(&"after".to_string()));
    }

    #[test]
    fn nested_block_comments() {
        let src = "/* a /* b */ still */ fn live() {}";
        let ids = idents(src);
        assert_eq!(ids, vec!["fn", "live"]);
    }

    #[test]
    fn char_literals_with_quote_slash_backslash() {
        for src in ["let q = '\"'; f();", "let s = '/'; f();", "let b = '\\''; f();",
                    "let w = '\\\\'; f();", "let y = b'x'; f();", "let z = b'\\''; f();"] {
            let ids = idents(src);
            assert!(ids.contains(&"f".to_string()), "f() lost in {src:?}");
            assert!(
                tokenize(src).iter().any(|t| t.kind == TokKind::Char),
                "no char literal found in {src:?}"
            );
        }
    }

    #[test]
    fn lifetimes_are_not_chars() {
        let toks = tokenize("fn f<'a>(x: &'a str, y: &'static u8) {}");
        let lts: Vec<_> =
            toks.iter().filter(|t| t.kind == TokKind::Lifetime).map(|t| t.text.clone()).collect();
        assert_eq!(lts, vec!["a", "a", "static"]);
        assert!(!toks.iter().any(|t| t.kind == TokKind::Char));
    }

    #[test]
    fn ranges_are_not_method_dots() {
        let toks = tokenize("for i in 0..n.len() {}");
        assert!(toks.iter().any(|t| t.is_punct("..")));
        // Exactly one bare `.` (the method dot before len).
        assert_eq!(toks.iter().filter(|t| t.is_punct(".")).count(), 1);
    }

    #[test]
    fn floats_and_tuple_fields() {
        let toks = tokenize("let a = 0.5; let b = x.0; let c = 1f32;");
        let nums: Vec<_> =
            toks.iter().filter(|t| t.kind == TokKind::Num).map(|t| t.text.clone()).collect();
        assert_eq!(nums, vec!["0.5", "0", "1f32"]);
    }

    #[test]
    fn compound_assign_is_one_token() {
        let toks = tokenize("total += v; total -= v; a != b; m!();");
        assert!(toks.iter().any(|t| t.is_punct("+=")));
        assert!(toks.iter().any(|t| t.is_punct("-=")));
        assert!(toks.iter().any(|t| t.is_punct("!=")));
        // Macro bang is a lone `!` directly after the ident.
        let i = toks.iter().position(|t| t.is_ident("m")).unwrap();
        assert!(toks[i + 1].is_punct("!"));
    }

    // -------------------------------------------------------------------
    // Property tests over generated token soups. A tiny deterministic
    // LCG drives a generator that emits source fragments while tracking
    // ground truth: which marker identifiers are real code and which are
    // buried in strings/comments/char literals. The lexer must recover
    // exactly the code markers; `scan.rs` (the line scanner the rules use)
    // must agree — this is the shared test bed for both implementations.
    // -------------------------------------------------------------------

    struct Lcg(u64);
    impl Lcg {
        fn next(&mut self) -> u64 {
            self.0 = self.0.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            self.0 >> 33
        }
        fn below(&mut self, n: u64) -> u64 {
            self.next() % n
        }
    }

    /// Emit one fragment; push code-visible markers into `code_marks`,
    /// buried ones into `hidden_marks`.
    fn gen_fragment(
        rng: &mut Lcg,
        idx: usize,
        src: &mut String,
        code_marks: &mut Vec<String>,
        hidden_marks: &mut Vec<String>,
    ) {
        let code_mark = format!("CODEMARK{idx}");
        let hid_mark = format!("HIDDENMARK{idx}");
        match rng.below(10) {
            0 => {
                src.push_str(&format!("let {code_mark} = 1;\n"));
                code_marks.push(code_mark);
            }
            1 => {
                src.push_str(&format!("// line comment {hid_mark}\n"));
                hidden_marks.push(hid_mark);
            }
            2 => {
                src.push_str(&format!("/* outer /* inner {hid_mark} */ tail */\n"));
                hidden_marks.push(hid_mark);
            }
            3 => {
                let hashes = "#".repeat(rng.below(3) as usize);
                src.push_str(&format!(
                    "let s{idx} = r{hashes}\"raw {hid_mark} \"{hashes}; {code_mark}();\n"
                ));
                code_marks.push(code_mark);
                hidden_marks.push(hid_mark);
            }
            4 => {
                src.push_str(&format!("let s{idx} = \"esc \\\" {hid_mark} \\\\\"; \n"));
                hidden_marks.push(hid_mark);
            }
            5 => {
                let lit = ["'\"'", "'/'", "'\\''", "'\\\\'", "b'q'"][rng.below(5) as usize];
                src.push_str(&format!("let c{idx} = {lit}; {code_mark}();\n"));
                code_marks.push(code_mark);
            }
            6 => {
                src.push_str(&format!("fn {code_mark}<'a>(x: &'a str) {{ x.len(); }}\n"));
                code_marks.push(code_mark);
            }
            7 => {
                src.push_str(&format!(
                    "let m{idx} = r#\"multi\nline {hid_mark}\n\"#; {code_mark}();\n"
                ));
                code_marks.push(code_mark);
                hidden_marks.push(hid_mark);
            }
            8 => {
                src.push_str(&format!("for i{idx} in 0..{code_mark} {{}}\n"));
                code_marks.push(code_mark);
            }
            _ => {
                src.push_str(&format!("let b{idx} = b\"bytes {hid_mark}\"; {code_mark}!();\n"));
                code_marks.push(code_mark);
                hidden_marks.push(hid_mark);
            }
        }
    }

    #[test]
    fn prop_lexer_and_scanner_agree_on_token_soups() {
        for seed in 0..24u64 {
            let mut rng = Lcg(seed * 7919 + 3);
            let mut src = String::new();
            let mut code_marks = Vec::new();
            let mut hidden_marks = Vec::new();
            let count = 8 + rng.below(24) as usize;
            for idx in 0..count {
                gen_fragment(&mut rng, idx, &mut src, &mut code_marks, &mut hidden_marks);
            }

            // Lexer view: every code marker is an Ident token, no hidden
            // marker ever surfaces as one.
            let ids: std::collections::HashSet<String> = tokenize(&src)
                .into_iter()
                .filter(|t| t.kind == TokKind::Ident)
                .map(|t| t.text)
                .collect();
            for m in &code_marks {
                assert!(ids.contains(m), "seed {seed}: lexer lost code marker {m}\n{src}");
            }
            for m in &hidden_marks {
                assert!(!ids.contains(m), "seed {seed}: lexer leaked hidden marker {m}\n{src}");
            }

            // Scanner view: the blanked `code` lines must agree.
            let sf = scan_source("rust/src/soup.rs", &src);
            let all_code: String = sf.lines.iter().map(|l| l.code.as_str()).collect::<Vec<_>>().join("\n");
            for m in &code_marks {
                assert!(all_code.contains(m), "seed {seed}: scanner lost code marker {m}\n{src}");
            }
            for m in &hidden_marks {
                assert!(!all_code.contains(m), "seed {seed}: scanner leaked hidden marker {m}\n{src}");
            }
        }
    }

    #[test]
    fn prop_lexer_never_panics_on_truncated_soups() {
        // Truncating mid-literal must not panic or loop forever.
        let mut rng = Lcg(99);
        let mut src = String::new();
        let (mut cm, mut hm) = (Vec::new(), Vec::new());
        for idx in 0..16 {
            gen_fragment(&mut rng, idx, &mut src, &mut cm, &mut hm);
        }
        for cut in 0..src.len() {
            if src.is_char_boundary(cut) {
                let _ = tokenize(&src[..cut]);
            }
        }
    }
}
