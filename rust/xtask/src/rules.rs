//! The repo invariants, as mechanical rules.
//!
//! Two layers share this module's `Finding` type and allow-marker
//! machinery:
//!
//! - **line rules** (here) — token/word checks over the scanned `code`
//!   view of each line: SAFETY comments on `unsafe`, no FMA tokens, no
//!   raw threads outside the pool, `EAC_MOE_*` env reads confined to
//!   `util/env.rs`.
//! - **graph analyses** ([`crate::analyses`]) — reachability-based checks
//!   over the call graph and module graph: transitive `serve-no-panic`
//!   (with printed call chains), `serve-unguarded-index`,
//!   `float-hash-order`, `no-fma-transitive`, `module-layering`.
//!
//! Each rule has a machine-readable ID and an inline escape hatch:
//! a `xtask-allow: <rule-id>` comment on the offending line (or the line
//! directly above it) suppresses that rule there — always with a short
//! justification, since the allow marker is the documentation. The
//! `no-fma` rule additionally honors region markers (`xtask-allow-region:`
//! … `xtask-end-region:`, id `no-fma`), but only inside
//! `rust/src/tensor/simd.rs` (the pinned-DAG kernel file); region markers
//! anywhere else are themselves violations. The transitive FMA rule
//! deliberately ignores inline `no-fma` allows outside that file: an
//! allow on a helper must not launder FMA into the kernel contract.
//!
//! Why each invariant exists:
//!
//! - `unsafe-safety-comment` — the unsafe surface (SIMD kernels, the
//!   lifetime-erased pool queue) is only auditable if every block states
//!   the precondition that makes it sound.
//! - `no-fma` / `no-fma-transitive` — the SIMD contract pins one
//!   operation DAG (separate mul then add, 8-lane split-sum reduction) so
//!   scalar/AVX2/NEON produce bit-identical f32 results. A fused
//!   multiply-add rounds once instead of twice and silently breaks every
//!   bit-identity test — wherever it sits in the call tree.
//! - `no-raw-thread` — compute rides the scoped worker pool in
//!   `tensor/pool.rs` (bounded threads, panic propagation, helping
//!   waiters). Ad-hoc `std::thread` spawns escape the thread budget and
//!   the pool's panic handling.
//! - `serve-no-panic` / `serve-unguarded-index` — anything reachable from
//!   the serve entry points must degrade by returning errors, not by
//!   unwinding mid-batch with locks held. Poisoned-lock `unwrap()`s are
//!   exempt: a poisoned lock means a worker already panicked, and
//!   propagating that panic is the correct response.
//! - `float-hash-order` — HashMap/HashSet iteration order is
//!   nondeterministic; accumulating floats in that order breaks the
//!   pinned operation DAG between runs on the *same* machine.
//! - `env-read-site` — `EAC_MOE_*` configuration is read once through
//!   `util/env.rs` accessors. Scattered `std::env::var` reads caused the
//!   PR 3 mid-run reconfiguration bug that the `OnceLock` latch fixed;
//!   `var_os` and the `vars`/`vars_os` iterators (which enumerate every
//!   `EAC_MOE_*` variable implicitly) count as reads too.
//! - `module-layering` — the module DAG in `rust/xtask/layering.toml` is
//!   the architecture; an edge outside it (or a cycle) is drift.

use crate::analyses;
use crate::items;
use crate::scan::{scan_source, SourceFile};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// (rule id, one-line description) — the lint surface.
pub const RULES: &[(&str, &str)] = &[
    (
        "unsafe-safety-comment",
        "every `unsafe` needs a `SAFETY:` comment on it or directly above",
    ),
    (
        "no-fma",
        "no fused multiply-add: kernels pin separate mul+add for bit-identity",
    ),
    (
        "no-fma-transitive",
        "no FMA anywhere reachable from the kernel contract files",
    ),
    (
        "no-raw-thread",
        "no raw std::thread outside tensor/pool.rs: compute rides the pool",
    ),
    (
        "serve-no-panic",
        "no unwrap/expect/panic reachable from the serve entry points (poisoned locks exempt)",
    ),
    (
        "serve-unguarded-index",
        "serve-reachable fns that index slices need a bounds guard in the body",
    ),
    (
        "float-hash-order",
        "no f32/f64 accumulation over HashMap/HashSet iteration order",
    ),
    (
        "env-read-site",
        "EAC_MOE_* env reads (var/var_os/vars) only in util/env.rs",
    ),
    (
        "module-layering",
        "module deps must match rust/xtask/layering.toml and stay acyclic",
    ),
];

/// Meta-rule id for marker misuse (unknown rule in a marker, region marker
/// outside its allowlisted file, unclosed region).
pub const META_RULE: &str = "xtask-marker";

/// Files allowed to open an allow-region, per rule.
const REGION_OK: &[(&str, &str)] = &[("no-fma", "rust/src/tensor/simd.rs")];

/// Directories scanned by `lint_tree`, relative to the repo root.
const SCAN_ROOTS: &[&str] = &[
    "rust/src",
    "rust/tests",
    "rust/benches",
    "rust/vendor",
    "rust/xtask/src",
    "examples",
];

/// Repo-relative path of the layering manifest.
pub const MANIFEST_REL: &str = "rust/xtask/layering.toml";

pub struct Finding {
    pub rel: String,
    /// 1-based line number.
    pub line: usize,
    pub rule: &'static str,
    pub msg: String,
}

pub struct LintReport {
    pub findings: Vec<Finding>,
    pub files_checked: usize,
}

fn known_rule(id: &str) -> Option<&'static str> {
    RULES.iter().map(|(r, _)| *r).find(|r| *r == id)
}

/// Extract every rule id following an occurrence of `marker` in comment
/// text. Ids are `[A-Za-z0-9_-]+`; anything else (e.g. a `<rule>`
/// placeholder in docs) is skipped.
fn marker_ids(comment: &str, marker: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut from = 0usize;
    while let Some(p) = comment[from..].find(marker) {
        let abs = from + p + marker.len();
        let rest = comment[abs..].trim_start();
        let end = rest
            .find(|c: char| !(c.is_ascii_alphanumeric() || c == '-' || c == '_'))
            .unwrap_or(rest.len());
        if end > 0 {
            out.push(rest[..end].to_string());
        }
        from = abs;
    }
    out
}

/// Find whole-word occurrences of `word` in `code` (neighbors must not be
/// identifier characters).
fn contains_word(code: &str, word: &str) -> bool {
    let bytes = code.as_bytes();
    let mut from = 0usize;
    while let Some(p) = code[from..].find(word) {
        let abs = from + p;
        let before_ok = abs == 0 || !bytes[abs - 1].is_ascii_alphanumeric() && bytes[abs - 1] != b'_';
        let after = abs + word.len();
        let after_ok =
            after >= bytes.len() || !bytes[after].is_ascii_alphanumeric() && bytes[after] != b'_';
        if before_ok && after_ok {
            return true;
        }
        from = abs + 1;
    }
    false
}

/// Does line `i` carry a SAFETY annotation, either on the line itself or
/// on a run of comment/attribute/blank lines directly above it?
fn has_safety(sf: &SourceFile, i: usize) -> bool {
    let marked = |c: &str| c.contains("SAFETY:") || c.contains("# Safety");
    if marked(&sf.lines[i].comment) {
        return true;
    }
    let mut j = i;
    while j > 0 {
        j -= 1;
        let l = &sf.lines[j];
        if marked(&l.comment) {
            return true;
        }
        let code = l.code.trim();
        if !(code.is_empty() || code.starts_with("#[") || code.starts_with("#!")) {
            return false;
        }
    }
    false
}

/// Pass 1: collect allow markers (inline + regions) into per-rule line
/// masks, reporting marker misuse as findings.
pub(crate) fn allow_masks(
    sf: &SourceFile,
    rel: &str,
) -> (HashMap<&'static str, Vec<bool>>, Vec<Finding>) {
    let n = sf.lines.len();
    let mut findings: Vec<Finding> = Vec::new();
    let mut allow: HashMap<&'static str, Vec<bool>> =
        RULES.iter().map(|(id, _)| (*id, vec![false; n])).collect();
    let mut regions_open: Vec<(&'static str, usize)> = Vec::new();
    for i in 0..n {
        let comment = sf.lines[i].comment.clone();
        for id in marker_ids(&comment, "xtask-allow-region:") {
            match known_rule(&id) {
                None => findings.push(Finding {
                    rel: rel.to_string(),
                    line: i + 1,
                    rule: META_RULE,
                    msg: format!("unknown rule `{id}` in xtask-allow-region marker"),
                }),
                Some(rid) => {
                    if REGION_OK.contains(&(rid, rel)) {
                        regions_open.push((rid, i));
                    } else {
                        findings.push(Finding {
                            rel: rel.to_string(),
                            line: i + 1,
                            rule: META_RULE,
                            msg: format!("allow-region for `{rid}` is not permitted in {rel}"),
                        });
                    }
                }
            }
        }
        for (rid, _) in &regions_open {
            allow.get_mut(rid).expect("known rule")[i] = true;
        }
        for id in marker_ids(&comment, "xtask-end-region:") {
            if let Some(rid) = known_rule(&id) {
                regions_open.retain(|(r, _)| *r != rid);
            }
        }
        for id in marker_ids(&comment, "xtask-allow:") {
            match known_rule(&id) {
                None => findings.push(Finding {
                    rel: rel.to_string(),
                    line: i + 1,
                    rule: META_RULE,
                    msg: format!("unknown rule `{id}` in xtask-allow marker"),
                }),
                Some(rid) => {
                    let v = allow.get_mut(rid).expect("known rule");
                    v[i] = true;
                    if i + 1 < n {
                        v[i + 1] = true;
                    }
                }
            }
        }
    }
    for (rid, start) in regions_open {
        findings.push(Finding {
            rel: rel.to_string(),
            line: start + 1,
            rule: META_RULE,
            msg: format!("unclosed xtask-allow-region for `{rid}`"),
        });
    }
    (allow, findings)
}

/// Pass 2: the per-line rules, filtered through the allow masks.
fn line_rules(
    sf: &SourceFile,
    rel: &str,
    allow: &HashMap<&'static str, Vec<bool>>,
) -> Vec<Finding> {
    let n = sf.lines.len();
    let mut findings: Vec<Finding> = Vec::new();
    let mut push = |i: usize, rule: &'static str, msg: String| {
        if !allow[rule][i] {
            findings.push(Finding { rel: rel.to_string(), line: i + 1, rule, msg });
        }
    };

    let in_util_env = rel == "rust/src/util/env.rs";
    let in_pool = rel == "rust/src/tensor/pool.rs";
    const FMA_TOKENS: &[&str] = &["mul_add", "fmadd", "vfma", "fmla"];
    const THREAD_TOKENS: &[&str] = &["thread::spawn", "thread::scope", "thread::Builder"];

    for i in 0..n {
        let code = &sf.lines[i].code;
        let test = sf.is_test[i];

        // Rule: unsafe-safety-comment (everywhere, tests included —
        // unsafe in tests needs the same audit trail).
        if contains_word(code, "unsafe") && !has_safety(sf, i) {
            push(
                i,
                "unsafe-safety-comment",
                "`unsafe` without an immediately preceding SAFETY comment".to_string(),
            );
        }

        // Rule: no-fma (everywhere — FMA breaks bit-identity in tests
        // exactly as much as in kernels).
        for tok in FMA_TOKENS {
            if code.contains(tok) {
                push(i, "no-fma", format!("fused multiply-add token `{tok}`"));
                break;
            }
        }

        // Rule: no-raw-thread (production code outside the pool).
        if !test && !in_pool {
            for tok in THREAD_TOKENS {
                if code.contains(tok) {
                    push(
                        i,
                        "no-raw-thread",
                        format!("raw `{tok}` outside tensor/pool.rs"),
                    );
                    break;
                }
            }
        }

        // Rule: env-read-site. `env::vars`/`vars_os` enumerate the whole
        // environment — every EAC_MOE_* variable implicitly — so they are
        // flagged outright. `env::var`/`var_os` are flagged when the read
        // names an EAC_MOE_ key; the prefix lives inside a string literal,
        // so it is matched against the raw line (plus a short lookahead
        // for calls split across lines).
        if !in_util_env {
            if code.contains("env::vars") {
                push(
                    i,
                    "env-read-site",
                    "`env::vars` enumerates the environment (EAC_MOE_* included) \
                     outside util/env.rs"
                        .to_string(),
                );
            } else if code.contains("env::var") {
                let mut window = sf.lines[i].raw.clone();
                for l in sf.lines.iter().take(n.min(i + 3)).skip(i + 1) {
                    window.push_str(&l.raw);
                }
                if window.contains("EAC_MOE_") {
                    push(
                        i,
                        "env-read-site",
                        "EAC_MOE_* env read outside util/env.rs".to_string(),
                    );
                }
            }
        }
    }
    findings
}

/// Lint one file's source text with the line rules only (the path decides
/// rule scoping, so tests can replay fixtures at synthetic locations).
/// Graph analyses need the whole file set — see [`lint_files`].
pub fn lint_source(rel: &str, text: &str) -> Vec<Finding> {
    let sf = scan_source(rel, text);
    let (allow, mut findings) = allow_masks(&sf, rel);
    findings.extend(line_rules(&sf, rel, &allow));
    findings
}

/// Lint a set of files: line rules on every file, graph analyses over the
/// `rust/src/` subset, layering against `manifest` (repo-relative path +
/// text) when given. Findings come back sorted by (file, line, rule).
pub fn lint_files(
    inputs: &[(String, String)],
    manifest: Option<(&str, &str)>,
    require_seeds: bool,
) -> Result<Vec<Finding>, String> {
    let mut findings: Vec<Finding> = Vec::new();
    let mut prepared: Vec<analyses::Prepared> = Vec::new();
    for (rel, text) in inputs {
        let sf = scan_source(rel, text);
        let (allow, marker_findings) = allow_masks(&sf, rel);
        findings.extend(marker_findings);
        findings.extend(line_rules(&sf, rel, &allow));
        prepared.push(analyses::Prepared { sf, items: items::extract(rel, text), allow });
    }
    let man = match manifest {
        Some((rel, text)) => Some(analyses::parse_manifest(rel, text)?),
        None => None,
    };
    findings.extend(analyses::run(&prepared, man.as_ref(), require_seeds)?);
    findings.sort_by(|a, b| {
        (a.rel.as_str(), a.line, a.rule).cmp(&(b.rel.as_str(), b.line, b.rule))
    });
    Ok(findings)
}

fn collect_rs(root: &Path, rel_dir: &str, out: &mut Vec<(String, PathBuf)>) {
    let dir = root.join(rel_dir);
    let Ok(rd) = std::fs::read_dir(&dir) else {
        return;
    };
    // Sort entries by name: readdir order is filesystem-dependent, and
    // stable finding order keeps CI lint output diffable across runners.
    let mut entries: Vec<(String, PathBuf)> = rd
        .flatten()
        .map(|e| (e.file_name().to_string_lossy().into_owned(), e.path()))
        .collect();
    entries.sort();
    for (name, path) in entries {
        if path.is_dir() {
            // `fixtures` holds deliberate violations; `target` is build output.
            if name == "target" || name == "fixtures" || name == ".git" {
                continue;
            }
            collect_rs(root, &format!("{rel_dir}/{name}"), out);
        } else if name.ends_with(".rs") {
            out.push((format!("{rel_dir}/{name}"), path));
        }
    }
}

/// Lint every `.rs` file under the scan roots of the repo at `root`,
/// including the graph analyses and the layering manifest (which must
/// exist — a tree without its architecture manifest fails the lint).
pub fn lint_tree(root: &Path) -> Result<LintReport, String> {
    if !root.join("rust/src").is_dir() {
        return Err(format!(
            "{} does not look like the repo root (missing rust/src); pass --root",
            root.display()
        ));
    }
    let mut files: Vec<(String, PathBuf)> = Vec::new();
    for r in SCAN_ROOTS {
        collect_rs(root, r, &mut files);
    }
    files.sort();
    let files_checked = files.len();
    let mut inputs: Vec<(String, String)> = Vec::with_capacity(files.len());
    for (rel, path) in files {
        let text = std::fs::read_to_string(&path)
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        inputs.push((rel, text));
    }
    let manifest_path = root.join(MANIFEST_REL);
    let manifest_text = std::fs::read_to_string(&manifest_path)
        .map_err(|e| format!("read {} (layering manifest is required): {e}", manifest_path.display()))?;
    let findings = lint_files(&inputs, Some((MANIFEST_REL, &manifest_text)), true)?;
    Ok(LintReport { findings, files_checked })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fixture(name: &str) -> String {
        let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("fixtures").join(name);
        std::fs::read_to_string(&p).unwrap_or_else(|e| panic!("read {}: {e}", p.display()))
    }

    /// Fixtures self-describe their expected findings: a line whose
    /// comment contains `LINT:<rule-id>` must produce exactly that
    /// finding. Returns sorted (line, rule) pairs.
    fn expected_markers(text: &str) -> Vec<(usize, String)> {
        let mut out = Vec::new();
        for (i, line) in text.lines().enumerate() {
            let mut from = 0usize;
            while let Some(p) = line[from..].find("LINT:") {
                let abs = from + p + "LINT:".len();
                let rest = &line[abs..];
                let end = rest
                    .find(|c: char| !(c.is_ascii_alphanumeric() || c == '-' || c == '_'))
                    .unwrap_or(rest.len());
                if end > 0 {
                    out.push((i + 1, rest[..end].to_string()));
                }
                from = abs;
            }
        }
        out.sort();
        out
    }

    fn check_fixture(rel: &str, name: &str) {
        let text = fixture(name);
        let expected = expected_markers(&text);
        let mut got: Vec<(usize, String)> = lint_source(rel, &text)
            .into_iter()
            .map(|f| (f.line, f.rule.to_string()))
            .collect();
        got.sort();
        assert_eq!(got, expected, "fixture {name} linted at {rel}");
    }

    /// Like `check_fixture`, but through the full pipeline (line rules +
    /// graph analyses), which the reachability rules need.
    fn check_graph_fixture(rel: &str, name: &str) {
        let text = fixture(name);
        let expected = expected_markers(&text);
        let findings =
            lint_files(&[(rel.to_string(), text)], None, false).expect("lint_files");
        let mut got: Vec<(usize, String)> =
            findings.into_iter().map(|f| (f.line, f.rule.to_string())).collect();
        got.sort();
        assert_eq!(got, expected, "fixture {name} linted at {rel}");
    }

    #[test]
    fn fixture_unsafe_requires_safety_comment() {
        check_fixture("rust/src/tensor/fixture.rs", "unsafe_no_safety.rs");
    }

    #[test]
    fn fixture_fma_is_rejected_and_region_gated() {
        check_fixture("rust/src/tensor/fixture.rs", "fma.rs");
    }

    #[test]
    fn fixture_raw_threads_are_rejected_outside_pool() {
        check_fixture("rust/src/serve/fixture.rs", "raw_thread.rs");
        // The same source inside the pool file is fine (minus its own
        // expectations, which assume a non-pool path), so just check the
        // rule scoping directly:
        let got = lint_source("rust/src/tensor/pool.rs", &fixture("raw_thread.rs"));
        assert!(got.iter().all(|f| f.rule != "no-raw-thread"));
    }

    #[test]
    fn fixture_serve_panics_are_found_transitively() {
        check_graph_fixture("rust/src/serve/fixture.rs", "serve_panic.rs");
    }

    #[test]
    fn serve_reachability_is_path_independent() {
        // The graph rule keys on entry points, not directory prefixes:
        // the same fixture replayed *outside* serve/ still has Engine::serve
        // and decode_step_batch, so the findings survive relocation —
        // exactly what the old path-prefix heuristic got wrong.
        let text = fixture("serve_panic.rs");
        let findings = lint_files(&[("rust/src/quant/fixture.rs".to_string(), text)], None, false)
            .expect("lint_files");
        assert!(
            findings.iter().any(|f| f.rule == "serve-no-panic"),
            "relocated fixture lost its reachability findings"
        );
    }

    #[test]
    fn serve_finding_messages_carry_the_call_chain() {
        let text = fixture("serve_panic.rs");
        let findings = lint_files(&[("rust/src/serve/fixture.rs".to_string(), text)], None, false)
            .expect("lint_files");
        let boom = findings
            .iter()
            .find(|f| f.rule == "serve-no-panic" && f.msg.contains("panic!"))
            .expect("panic finding");
        assert!(
            boom.msg.contains("Engine::serve → fixture::dispatch → fixture::boom"),
            "chain missing or wrong: {}",
            boom.msg
        );
    }

    #[test]
    fn missing_seeds_error_when_required() {
        let files =
            vec![("rust/src/quant/alone.rs".to_string(), "pub fn f() {}".to_string())];
        let err = lint_files(&files, None, true).unwrap_err();
        assert!(err.contains("seed"), "unexpected error: {err}");
        // Without the requirement the same tree lints clean.
        assert!(lint_files(&files, None, false).expect("lint").is_empty());
    }

    #[test]
    fn fixture_float_hash_order() {
        check_graph_fixture("rust/src/calib/fixture.rs", "float_hash.rs");
    }

    #[test]
    fn fixture_fma_transitive_ignores_inline_allows() {
        // Replayed at a kernel contract file: the inline `no-fma` allow
        // silences the line rule but not the transitive one.
        check_graph_fixture("rust/src/tensor/matmul.rs", "fma_transitive.rs");
        // Outside the contract region the transitive rule has no seeds
        // here, so only the (allowed) line rule applies → clean.
        let text = fixture("fma_transitive.rs");
        let findings = lint_files(&[("rust/src/calib/fixture.rs".to_string(), text)], None, false)
            .expect("lint_files");
        assert!(
            findings.is_empty(),
            "transitive FMA leaked outside the contract region: {:?}",
            dump(&findings)
        );
    }

    #[test]
    fn layering_edge_and_coverage_violations() {
        let files = vec![
            (
                "rust/src/util/env.rs".to_string(),
                "pub fn threads() -> usize { 1 }".to_string(),
            ),
            (
                "rust/src/tensor/ops.rs".to_string(),
                "use crate::serve::Engine;\npub fn f() {}".to_string(),
            ),
            (
                "rust/src/serve/engine.rs".to_string(),
                "pub struct Engine;".to_string(),
            ),
        ];
        let manifest = "util = []\ntensor = [\"util\"]\nserve = \"*\"\nghost = []\n";
        let findings = lint_files(&files, Some(("rust/xtask/layering.toml", manifest)), false)
            .expect("lint_files");
        let msgs: Vec<&str> = findings.iter().map(|f| f.msg.as_str()).collect();
        assert!(
            findings
                .iter()
                .any(|f| f.rule == "module-layering"
                    && f.rel == "rust/src/tensor/ops.rs"
                    && f.line == 1
                    && f.msg.contains("must not depend on `serve`")),
            "missing disallowed-edge finding: {msgs:?}"
        );
        assert!(
            findings.iter().any(|f| f.msg.contains("`ghost` matches no module")),
            "missing unknown-entry finding: {msgs:?}"
        );
    }

    #[test]
    fn layering_uncovered_module_and_cycle() {
        let files = vec![
            ("rust/src/a/mod.rs".to_string(), "use crate::b::X;\npub struct Z;".to_string()),
            ("rust/src/b/mod.rs".to_string(), "use crate::a::Z;\npub struct X;".to_string()),
        ];
        let manifest = "a = [\"b\"]\n";
        let findings = lint_files(&files, Some(("rust/xtask/layering.toml", manifest)), false)
            .expect("lint_files");
        assert!(
            findings.iter().any(|f| f.msg.contains("`b` has no entry")),
            "missing uncovered-module finding: {:?}",
            dump(&findings)
        );
        assert!(
            findings.iter().any(|f| f.msg.contains("dependency cycle")),
            "missing cycle finding: {:?}",
            dump(&findings)
        );
    }

    #[test]
    fn bad_manifest_is_an_error() {
        let files =
            vec![("rust/src/a/mod.rs".to_string(), "pub fn f() {}".to_string())];
        let err = lint_files(&files, Some(("rust/xtask/layering.toml", "a = 7\n")), false)
            .unwrap_err();
        assert!(err.contains("layering.toml"), "unexpected error: {err}");
    }

    #[test]
    fn fixture_env_reads_are_confined() {
        check_fixture("rust/src/report/fixture.rs", "env_read.rs");
        let got = lint_source("rust/src/util/env.rs", &fixture("env_read.rs"));
        assert!(got.is_empty(), "env-read-site flagged util/env.rs: {:?}", dump(&got));
    }

    #[test]
    fn fixture_clean_file_has_no_findings() {
        // Through the full pipeline, at a serve path, so every rule is in
        // scope.
        let text = fixture("clean.rs");
        let findings = lint_files(&[("rust/src/serve/clean.rs".to_string(), text)], None, false)
            .expect("lint_files");
        assert!(findings.is_empty(), "clean fixture tripped rules: {:?}", dump(&findings));
    }

    #[test]
    fn fixture_fma_region_is_honored_in_simd_only() {
        // The same region-marked source is clean inside the pinned-DAG
        // kernel file…
        let got = lint_source("rust/src/tensor/simd.rs", &fixture("fma_region_ok.rs"));
        assert!(got.is_empty(), "authorized region still flagged: {:?}", dump(&got));
    }

    #[test]
    fn unclosed_region_is_flagged() {
        let src = "// xtask-allow-region: no-fma\npub fn f() {}\n";
        let got = lint_source("rust/src/tensor/simd.rs", src);
        assert_eq!(got.len(), 1, "{:?}", dump(&got));
        assert_eq!(got[0].rule, META_RULE);
        assert_eq!(got[0].line, 1);
    }

    #[test]
    fn unknown_rule_in_marker_is_flagged() {
        let src = "// xtask-allow: not-a-rule\npub fn f() {}\n";
        let got = lint_source("rust/src/quant/x.rs", src);
        assert_eq!(got.len(), 1, "{:?}", dump(&got));
        assert_eq!(got[0].rule, META_RULE);
    }

    #[test]
    fn real_tree_is_clean() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR"))
            .parent()
            .and_then(Path::parent)
            .expect("xtask sits two levels under the repo root");
        let report = lint_tree(root).expect("lint tree");
        assert!(report.files_checked > 20, "scan roots missing files");
        assert!(
            report.findings.is_empty(),
            "tree has violations:\n{}",
            dump(&report.findings).join("\n")
        );
    }

    fn dump(fs: &[Finding]) -> Vec<String> {
        fs.iter()
            .map(|f| format!("{}:{}: [{}] {}", f.rel, f.line, f.rule, f.msg))
            .collect()
    }
}
