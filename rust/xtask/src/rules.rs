//! The repo invariants, as mechanical rules.
//!
//! Each rule has a machine-readable ID and an inline escape hatch:
//! a `xtask-allow: <rule-id>` comment on the offending line (or the line
//! directly above it) suppresses that rule there — always with a short
//! justification, since the allow marker is the documentation. The
//! `no-fma` rule additionally honors region markers (`xtask-allow-region:`
//! … `xtask-end-region:`, id `no-fma`), but only inside
//! `rust/src/tensor/simd.rs` (the pinned-DAG kernel file); region markers
//! anywhere else are themselves violations.
//!
//! Why each invariant exists:
//!
//! - `unsafe-safety-comment` — the unsafe surface (SIMD kernels, the
//!   lifetime-erased pool queue) is only auditable if every block states
//!   the precondition that makes it sound.
//! - `no-fma` — the SIMD contract pins one operation DAG (separate mul
//!   then add, 8-lane split-sum reduction) so scalar/AVX2/NEON produce
//!   bit-identical f32 results. A fused multiply-add rounds once instead
//!   of twice and silently breaks every bit-identity test.
//! - `no-raw-thread` — compute rides the scoped worker pool in
//!   `tensor/pool.rs` (bounded threads, panic propagation, helping
//!   waiters). Ad-hoc `std::thread` spawns escape the thread budget and
//!   the pool's panic handling.
//! - `serve-no-panic` — the serve hot path (`serve/`, `model/store.rs`,
//!   `model/forward.rs`) must degrade by returning errors, not by
//!   unwinding mid-batch with locks held. Poisoned-lock `unwrap()`s are
//!   exempt: a poisoned lock means a worker already panicked, and
//!   propagating that panic is the correct response.
//! - `env-read-site` — `EAC_MOE_*` configuration is read once through
//!   `util/env.rs` accessors. Scattered `std::env::var` reads caused the
//!   PR 3 mid-run reconfiguration bug that the `OnceLock` latch fixed.

use crate::scan::{scan_source, SourceFile};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// (rule id, one-line description) — the lint surface.
pub const RULES: &[(&str, &str)] = &[
    (
        "unsafe-safety-comment",
        "every `unsafe` needs a `SAFETY:` comment on it or directly above",
    ),
    (
        "no-fma",
        "no fused multiply-add: kernels pin separate mul+add for bit-identity",
    ),
    (
        "no-raw-thread",
        "no raw std::thread outside tensor/pool.rs: compute rides the pool",
    ),
    (
        "serve-no-panic",
        "no unwrap/expect/panic in the serve hot path (poisoned locks exempt)",
    ),
    (
        "env-read-site",
        "EAC_MOE_* env reads only in util/env.rs (config is read once)",
    ),
];

/// Meta-rule id for marker misuse (unknown rule in a marker, region marker
/// outside its allowlisted file, unclosed region).
pub const META_RULE: &str = "xtask-marker";

/// Files allowed to open an allow-region, per rule.
const REGION_OK: &[(&str, &str)] = &[("no-fma", "rust/src/tensor/simd.rs")];

/// Directories scanned by `lint_tree`, relative to the repo root.
const SCAN_ROOTS: &[&str] = &[
    "rust/src",
    "rust/tests",
    "rust/benches",
    "rust/vendor",
    "rust/xtask/src",
    "examples",
];

pub struct Finding {
    pub rel: String,
    /// 1-based line number.
    pub line: usize,
    pub rule: &'static str,
    pub msg: String,
}

pub struct LintReport {
    pub findings: Vec<Finding>,
    pub files_checked: usize,
}

fn known_rule(id: &str) -> Option<&'static str> {
    RULES.iter().map(|(r, _)| *r).find(|r| *r == id)
}

/// Extract every rule id following an occurrence of `marker` in comment
/// text. Ids are `[A-Za-z0-9_-]+`; anything else (e.g. a `<rule>`
/// placeholder in docs) is skipped.
fn marker_ids(comment: &str, marker: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut from = 0usize;
    while let Some(p) = comment[from..].find(marker) {
        let abs = from + p + marker.len();
        let rest = comment[abs..].trim_start();
        let end = rest
            .find(|c: char| !(c.is_ascii_alphanumeric() || c == '-' || c == '_'))
            .unwrap_or(rest.len());
        if end > 0 {
            out.push(rest[..end].to_string());
        }
        from = abs;
    }
    out
}

/// Find whole-word occurrences of `word` in `code` (neighbors must not be
/// identifier characters).
fn contains_word(code: &str, word: &str) -> bool {
    let bytes = code.as_bytes();
    let mut from = 0usize;
    while let Some(p) = code[from..].find(word) {
        let abs = from + p;
        let before_ok = abs == 0 || !bytes[abs - 1].is_ascii_alphanumeric() && bytes[abs - 1] != b'_';
        let after = abs + word.len();
        let after_ok =
            after >= bytes.len() || !bytes[after].is_ascii_alphanumeric() && bytes[after] != b'_';
        if before_ok && after_ok {
            return true;
        }
        from = abs + 1;
    }
    false
}

/// Does line `i` carry a SAFETY annotation, either on the line itself or
/// on a run of comment/attribute/blank lines directly above it?
fn has_safety(sf: &SourceFile, i: usize) -> bool {
    let marked = |c: &str| c.contains("SAFETY:") || c.contains("# Safety");
    if marked(&sf.lines[i].comment) {
        return true;
    }
    let mut j = i;
    while j > 0 {
        j -= 1;
        let l = &sf.lines[j];
        if marked(&l.comment) {
            return true;
        }
        let code = l.code.trim();
        if !(code.is_empty() || code.starts_with("#[") || code.starts_with("#!")) {
            return false;
        }
    }
    false
}

/// Is the `.unwrap()` whose `.` sits at byte `dot` in `code` hanging off a
/// `lock(…)` / `wait(…)` / `wait_timeout(…)` call? Those unwraps only fire
/// on lock poisoning — i.e. a worker already panicked — and are exempt
/// from `serve-no-panic`. The receiver call must close on the same line;
/// anything else is conservatively a violation.
fn is_poison_unwrap(code: &str, dot: usize) -> bool {
    let b: Vec<char> = code[..dot].chars().collect();
    let mut i = b.len();
    if i == 0 || b[i - 1] != ')' {
        return false;
    }
    let mut depth = 0i32;
    while i > 0 {
        i -= 1;
        match b[i] {
            ')' => depth += 1,
            '(' => {
                depth -= 1;
                if depth == 0 {
                    break;
                }
            }
            _ => {}
        }
    }
    if depth != 0 {
        return false;
    }
    let end = i;
    let mut s = i;
    while s > 0 && is_ident_char(b[s - 1]) {
        s -= 1;
    }
    let name: String = b[s..end].iter().collect();
    matches!(name.as_str(), "lock" | "wait" | "wait_timeout")
}

fn is_ident_char(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

fn serve_hot_path(rel: &str) -> bool {
    rel.starts_with("rust/src/serve/")
        || rel == "rust/src/model/store.rs"
        || rel == "rust/src/model/forward.rs"
}

/// Lint one file's source text under the given repo-relative path (the
/// path decides rule scoping, so tests can replay fixtures at synthetic
/// locations).
pub fn lint_source(rel: &str, text: &str) -> Vec<Finding> {
    let sf = scan_source(rel, text);
    let n = sf.lines.len();
    let mut findings: Vec<Finding> = Vec::new();

    // Pass 1: collect allow markers (inline + regions).
    let mut allow: HashMap<&'static str, Vec<bool>> =
        RULES.iter().map(|(id, _)| (*id, vec![false; n])).collect();
    let mut regions_open: Vec<(&'static str, usize)> = Vec::new();
    for i in 0..n {
        let comment = sf.lines[i].comment.clone();
        for id in marker_ids(&comment, "xtask-allow-region:") {
            match known_rule(&id) {
                None => findings.push(Finding {
                    rel: rel.to_string(),
                    line: i + 1,
                    rule: META_RULE,
                    msg: format!("unknown rule `{id}` in xtask-allow-region marker"),
                }),
                Some(rid) => {
                    if REGION_OK.contains(&(rid, rel)) {
                        regions_open.push((rid, i));
                    } else {
                        findings.push(Finding {
                            rel: rel.to_string(),
                            line: i + 1,
                            rule: META_RULE,
                            msg: format!("allow-region for `{rid}` is not permitted in {rel}"),
                        });
                    }
                }
            }
        }
        for (rid, _) in &regions_open {
            allow.get_mut(rid).expect("known rule")[i] = true;
        }
        for id in marker_ids(&comment, "xtask-end-region:") {
            if let Some(rid) = known_rule(&id) {
                regions_open.retain(|(r, _)| *r != rid);
            }
        }
        for id in marker_ids(&comment, "xtask-allow:") {
            match known_rule(&id) {
                None => findings.push(Finding {
                    rel: rel.to_string(),
                    line: i + 1,
                    rule: META_RULE,
                    msg: format!("unknown rule `{id}` in xtask-allow marker"),
                }),
                Some(rid) => {
                    let v = allow.get_mut(rid).expect("known rule");
                    v[i] = true;
                    if i + 1 < n {
                        v[i + 1] = true;
                    }
                }
            }
        }
    }
    for (rid, start) in regions_open {
        findings.push(Finding {
            rel: rel.to_string(),
            line: start + 1,
            rule: META_RULE,
            msg: format!("unclosed xtask-allow-region for `{rid}`"),
        });
    }

    // Pass 2: rules. Candidates are filtered through the allow mask.
    let mut push = |i: usize, rule: &'static str, msg: String| {
        if !allow[rule][i] {
            findings.push(Finding { rel: rel.to_string(), line: i + 1, rule, msg });
        }
    };

    let in_util_env = rel == "rust/src/util/env.rs";
    let in_pool = rel == "rust/src/tensor/pool.rs";
    let hot = serve_hot_path(rel);
    const FMA_TOKENS: &[&str] = &["mul_add", "fmadd", "vfma", "fmla"];
    const THREAD_TOKENS: &[&str] = &["thread::spawn", "thread::scope", "thread::Builder"];
    const PANIC_TOKENS: &[&str] = &["panic!", "unreachable!", "todo!", "unimplemented!"];

    for i in 0..n {
        let code = &sf.lines[i].code;
        let test = sf.is_test[i];

        // Rule 1: unsafe-safety-comment (everywhere, tests included —
        // unsafe in tests needs the same audit trail).
        if contains_word(code, "unsafe") && !has_safety(&sf, i) {
            push(
                i,
                "unsafe-safety-comment",
                "`unsafe` without an immediately preceding SAFETY comment".to_string(),
            );
        }

        // Rule 2: no-fma (everywhere — FMA breaks bit-identity in tests
        // exactly as much as in kernels).
        for tok in FMA_TOKENS {
            if code.contains(tok) {
                push(i, "no-fma", format!("fused multiply-add token `{tok}`"));
                break;
            }
        }

        // Rule 3: no-raw-thread (production code outside the pool).
        if !test && !in_pool {
            for tok in THREAD_TOKENS {
                if code.contains(tok) {
                    push(
                        i,
                        "no-raw-thread",
                        format!("raw `{tok}` outside tensor/pool.rs"),
                    );
                    break;
                }
            }
        }

        // Rule 4: serve-no-panic (hot-path files, non-test lines).
        if hot && !test {
            for tok in PANIC_TOKENS {
                if code.contains(tok) {
                    push(i, "serve-no-panic", format!("`{tok}` in the serve hot path"));
                    break;
                }
            }
            if code.contains(".expect(") {
                push(i, "serve-no-panic", "`.expect(…)` in the serve hot path".to_string());
            }
            let mut from = 0usize;
            while let Some(p) = code[from..].find(".unwrap()") {
                let abs = from + p;
                from = abs + 1;
                if !is_poison_unwrap(code, abs) {
                    push(
                        i,
                        "serve-no-panic",
                        "`.unwrap()` in the serve hot path (not a poisoned-lock unwrap)"
                            .to_string(),
                    );
                    break;
                }
            }
        }

        // Rule 5: env-read-site. The EAC_MOE_ prefix lives inside a string
        // literal, so it is matched against the raw line (plus a short
        // lookahead for calls split across lines).
        if !in_util_env && code.contains("env::var") {
            let mut window = sf.lines[i].raw.clone();
            for l in sf.lines.iter().take(n.min(i + 3)).skip(i + 1) {
                window.push_str(&l.raw);
            }
            if window.contains("EAC_MOE_") {
                push(
                    i,
                    "env-read-site",
                    "EAC_MOE_* env read outside util/env.rs".to_string(),
                );
            }
        }
    }
    findings
}

fn collect_rs(root: &Path, rel_dir: &str, out: &mut Vec<(String, PathBuf)>) {
    let dir = root.join(rel_dir);
    let Ok(rd) = std::fs::read_dir(&dir) else {
        return;
    };
    for entry in rd.flatten() {
        let path = entry.path();
        let name = entry.file_name().to_string_lossy().into_owned();
        if path.is_dir() {
            // `fixtures` holds deliberate violations; `target` is build output.
            if name == "target" || name == "fixtures" || name == ".git" {
                continue;
            }
            collect_rs(root, &format!("{rel_dir}/{name}"), out);
        } else if name.ends_with(".rs") {
            out.push((format!("{rel_dir}/{name}"), path));
        }
    }
}

/// Lint every `.rs` file under the scan roots of the repo at `root`.
pub fn lint_tree(root: &Path) -> Result<LintReport, String> {
    if !root.join("rust/src").is_dir() {
        return Err(format!(
            "{} does not look like the repo root (missing rust/src); pass --root",
            root.display()
        ));
    }
    let mut files: Vec<(String, PathBuf)> = Vec::new();
    for r in SCAN_ROOTS {
        collect_rs(root, r, &mut files);
    }
    files.sort();
    let mut findings = Vec::new();
    let files_checked = files.len();
    for (rel, path) in files {
        let text = std::fs::read_to_string(&path)
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        findings.extend(lint_source(&rel, &text));
    }
    Ok(LintReport { findings, files_checked })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fixture(name: &str) -> String {
        let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("fixtures").join(name);
        std::fs::read_to_string(&p).unwrap_or_else(|e| panic!("read {}: {e}", p.display()))
    }

    /// Fixtures self-describe their expected findings: a line whose
    /// comment contains `LINT:<rule-id>` must produce exactly that
    /// finding. Returns sorted (line, rule) pairs.
    fn expected_markers(text: &str) -> Vec<(usize, String)> {
        let mut out = Vec::new();
        for (i, line) in text.lines().enumerate() {
            let mut from = 0usize;
            while let Some(p) = line[from..].find("LINT:") {
                let abs = from + p + "LINT:".len();
                let rest = &line[abs..];
                let end = rest
                    .find(|c: char| !(c.is_ascii_alphanumeric() || c == '-' || c == '_'))
                    .unwrap_or(rest.len());
                if end > 0 {
                    out.push((i + 1, rest[..end].to_string()));
                }
                from = abs;
            }
        }
        out.sort();
        out
    }

    fn check_fixture(rel: &str, name: &str) {
        let text = fixture(name);
        let expected = expected_markers(&text);
        let mut got: Vec<(usize, String)> = lint_source(rel, &text)
            .into_iter()
            .map(|f| (f.line, f.rule.to_string()))
            .collect();
        got.sort();
        assert_eq!(got, expected, "fixture {name} linted at {rel}");
    }

    #[test]
    fn fixture_unsafe_requires_safety_comment() {
        check_fixture("rust/src/tensor/fixture.rs", "unsafe_no_safety.rs");
    }

    #[test]
    fn fixture_fma_is_rejected_and_region_gated() {
        check_fixture("rust/src/tensor/fixture.rs", "fma.rs");
    }

    #[test]
    fn fixture_raw_threads_are_rejected_outside_pool() {
        check_fixture("rust/src/serve/fixture.rs", "raw_thread.rs");
        // The same source inside the pool file is fine (minus its own
        // expectations, which assume a non-pool path), so just check the
        // rule scoping directly:
        let got = lint_source("rust/src/tensor/pool.rs", &fixture("raw_thread.rs"));
        assert!(got.iter().all(|f| f.rule != "no-raw-thread"));
    }

    #[test]
    fn fixture_serve_panics_are_rejected_in_scope_only() {
        check_fixture("rust/src/serve/fixture.rs", "serve_panic.rs");
        // Outside the hot path the same file is clean.
        let got = lint_source("rust/src/quant/fixture.rs", &fixture("serve_panic.rs"));
        assert!(got.is_empty(), "serve-no-panic leaked out of scope: {:?}", dump(&got));
    }

    #[test]
    fn fixture_env_reads_are_confined() {
        check_fixture("rust/src/report/fixture.rs", "env_read.rs");
        let got = lint_source("rust/src/util/env.rs", &fixture("env_read.rs"));
        assert!(got.is_empty(), "env-read-site flagged util/env.rs: {:?}", dump(&got));
    }

    #[test]
    fn fixture_clean_file_has_no_findings() {
        // Linted at a hot-path rel so every rule is in scope.
        let got = lint_source("rust/src/serve/clean.rs", &fixture("clean.rs"));
        assert!(got.is_empty(), "clean fixture tripped rules: {:?}", dump(&got));
    }

    #[test]
    fn fixture_fma_region_is_honored_in_simd_only() {
        // The same region-marked source is clean inside the pinned-DAG
        // kernel file…
        let got = lint_source("rust/src/tensor/simd.rs", &fixture("fma_region_ok.rs"));
        assert!(got.is_empty(), "authorized region still flagged: {:?}", dump(&got));
    }

    #[test]
    fn unclosed_region_is_flagged() {
        let src = "// xtask-allow-region: no-fma\npub fn f() {}\n";
        let got = lint_source("rust/src/tensor/simd.rs", src);
        assert_eq!(got.len(), 1, "{:?}", dump(&got));
        assert_eq!(got[0].rule, META_RULE);
        assert_eq!(got[0].line, 1);
    }

    #[test]
    fn unknown_rule_in_marker_is_flagged() {
        let src = "// xtask-allow: not-a-rule\npub fn f() {}\n";
        let got = lint_source("rust/src/quant/x.rs", src);
        assert_eq!(got.len(), 1, "{:?}", dump(&got));
        assert_eq!(got[0].rule, META_RULE);
    }

    #[test]
    fn real_tree_is_clean() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR"))
            .parent()
            .and_then(Path::parent)
            .expect("xtask sits two levels under the repo root");
        let report = lint_tree(root).expect("lint tree");
        assert!(report.files_checked > 20, "scan roots missing files");
        assert!(
            report.findings.is_empty(),
            "tree has violations:\n{}",
            dump(&report.findings).join("\n")
        );
    }

    fn dump(fs: &[Finding]) -> Vec<String> {
        fs.iter()
            .map(|f| format!("{}:{}: [{}] {}", f.rel, f.line, f.rule, f.msg))
            .collect()
    }
}
