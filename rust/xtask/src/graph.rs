//! The two graphs the transitive analyses run on.
//!
//! **Call graph** — nodes are the functions extracted by [`crate::items`],
//! edges come from call-site extraction over body tokens. Resolution is
//! name-based and deliberately over-approximate in the safe direction
//! (more edges → more reachability → more findings, never fewer):
//!
//! - `recv.name(…)` (method call) links to *every* crate method named
//!   `name`;
//! - `Qual::name(…)` prefers candidates whose `impl` type or module
//!   matches the qualifier (`Self` resolves to the caller's impl type),
//!   falling back to all candidates when nothing matches;
//! - `name(…)` (free call) prefers same-file candidates (a local `fn`
//!   cannot be shadowed by an import — that would be ambiguous), falling
//!   back to all candidates.
//!
//! Unresolved names (std, vendored crates) produce no edges. Tokens owned
//! by a nested `fn` are attributed to the nested function only.
//!
//! **Module graph** — top-level `rust/src` modules with an edge `a → b`
//! for every non-test `use crate::b::…` declaration or inline
//! `crate::b::…` path in a file of module `a` (`super::` paths are
//! resolved against the file's module first). Each edge remembers its
//! first evidence site for error reporting. `lib.rs` and `main.rs` are
//! crate roots and exempt.
//!
//! Reachability ([`CallGraph::reach`]) is a BFS that records parent links,
//! so every finding can print the call chain that makes it reachable —
//! the analyzer's answer to "why is this function on the hot path?".

use crate::items::{file_module, FileItems};
use crate::lexer::{Tok, TokKind};
use std::collections::{BTreeMap, BTreeSet, HashMap, VecDeque};

/// One function node in the flattened call graph.
pub struct FnNode {
    /// Index into the `files` slice the graph was built from.
    pub file: usize,
    /// Index into that file's `fns`.
    pub item: usize,
}

pub struct CallGraph {
    pub nodes: Vec<FnNode>,
    /// Adjacency: callee node ids per caller node id (deduped, sorted).
    pub edges: Vec<Vec<usize>>,
    /// Per file, per token: the innermost fn (local index) owning it.
    owners: Vec<Vec<Option<usize>>>,
}

/// Human-readable label for a node: `Engine::serve` or `module::free_fn`.
pub fn node_label(files: &[&FileItems], node: &FnNode) -> String {
    let f = &files[node.file].fns[node.item];
    match &f.impl_type {
        Some(t) => format!("{t}::{}", f.name),
        None => match f.module.last() {
            Some(m) => format!("{m}::{}", f.name),
            None => f.name.clone(),
        },
    }
}

enum CallKind {
    Free,
    Method,
    Path(Vec<String>),
}

struct CallSite {
    name: String,
    kind: CallKind,
}

impl CallGraph {
    pub fn build(files: &[&FileItems]) -> CallGraph {
        let mut nodes: Vec<FnNode> = Vec::new();
        for (fi, f) in files.iter().enumerate() {
            for idx in 0..f.fns.len() {
                nodes.push(FnNode { file: fi, item: idx });
            }
        }
        let mut by_name: HashMap<&str, Vec<usize>> = HashMap::new();
        for (id, n) in nodes.iter().enumerate() {
            by_name.entry(&files[n.file].fns[n.item].name).or_default().push(id);
        }

        // Token ownership per file: innermost fn body wins, so a nested
        // fn's calls are not attributed to its parent.
        let owners: Vec<Vec<Option<usize>>> = files
            .iter()
            .map(|f| {
                let mut own: Vec<Option<usize>> = vec![None; f.toks.len()];
                let mut order: Vec<usize> = (0..f.fns.len()).collect();
                // Wider bodies first, so inner (narrower) ranges overwrite.
                order.sort_by_key(|&i| std::cmp::Reverse(f.fns[i].body.len()));
                for i in order {
                    for t in f.fns[i].body.clone() {
                        own[t] = Some(i);
                    }
                }
                own
            })
            .collect();

        let mut edges: Vec<Vec<usize>> = vec![Vec::new(); nodes.len()];
        for (id, node) in nodes.iter().enumerate() {
            let f = &files[node.file];
            let item = &f.fns[node.item];
            for j in item.body.clone() {
                if owners[node.file][j] != Some(node.item) {
                    continue;
                }
                let Some(site) = call_site_at(&f.toks, j) else {
                    continue;
                };
                let callees = resolve(&site, node, &nodes, &by_name, files);
                for c in callees {
                    if c != id {
                        edges[id].push(c);
                    }
                }
            }
            edges[id].sort_unstable();
            edges[id].dedup();
        }
        CallGraph { nodes, edges, owners }
    }

    /// Innermost fn (local index within its file) owning token `tok` of
    /// file `file`.
    pub fn owner(&self, file: usize, tok: usize) -> Option<usize> {
        self.owners.get(file).and_then(|v| v.get(tok)).copied().flatten()
    }

    /// BFS from `seeds`; returns `parent[node] = Some(caller)` for every
    /// reachable node (seeds map to themselves).
    pub fn reach(&self, seeds: &[usize]) -> Vec<Option<usize>> {
        let mut parent: Vec<Option<usize>> = vec![None; self.nodes.len()];
        let mut seen = vec![false; self.nodes.len()];
        let mut q: VecDeque<usize> = VecDeque::new();
        for &s in seeds {
            if !seen[s] {
                seen[s] = true;
                parent[s] = Some(s);
                q.push_back(s);
            }
        }
        while let Some(u) = q.pop_front() {
            for &v in &self.edges[u] {
                if !seen[v] {
                    seen[v] = true;
                    parent[v] = Some(u);
                    q.push_back(v);
                }
            }
        }
        parent
    }

    /// Call chain from a seed to `node`, as `A::b → C::d` labels. Longest
    /// chains are elided in the middle.
    pub fn chain(&self, files: &[&FileItems], parent: &[Option<usize>], node: usize) -> String {
        let mut path: Vec<usize> = vec![node];
        let mut cur = node;
        while let Some(p) = parent[cur] {
            if p == cur {
                break;
            }
            path.push(p);
            cur = p;
        }
        path.reverse();
        let labels: Vec<String> =
            path.iter().map(|&id| node_label(files, &self.nodes[id])).collect();
        if labels.len() > 8 {
            let head = &labels[..4];
            let tail = &labels[labels.len() - 3..];
            format!("{} → … → {}", head.join(" → "), tail.join(" → "))
        } else {
            labels.join(" → ")
        }
    }
}

/// If token `j` is the name of a call (`name(` with a non-definition,
/// non-macro context), classify it.
fn call_site_at(toks: &[Tok], j: usize) -> Option<CallSite> {
    let t = toks.get(j)?;
    if t.kind != TokKind::Ident {
        return None;
    }
    if !toks.get(j + 1)?.is_punct("(") {
        return None;
    }
    let prev = j.checked_sub(1).map(|k| &toks[k]);
    if let Some(p) = prev {
        if p.is_ident("fn") {
            return None; // definition
        }
        if p.is_punct(".") {
            return Some(CallSite { name: t.text.clone(), kind: CallKind::Method });
        }
        if p.is_punct("::") {
            // Walk the qualifier path back: `a::b::name(` → [a, b].
            let mut segs: Vec<String> = Vec::new();
            let mut k = j - 1;
            while k >= 1
                && toks[k].is_punct("::")
                && toks[k - 1].kind == TokKind::Ident
            {
                segs.push(toks[k - 1].text.clone());
                if k < 2 {
                    break;
                }
                k -= 2;
            }
            segs.reverse();
            return Some(CallSite { name: t.text.clone(), kind: CallKind::Path(segs) });
        }
    }
    Some(CallSite { name: t.text.clone(), kind: CallKind::Free })
}

fn resolve(
    site: &CallSite,
    caller: &FnNode,
    nodes: &[FnNode],
    by_name: &HashMap<&str, Vec<usize>>,
    files: &[&FileItems],
) -> Vec<usize> {
    let Some(cands) = by_name.get(site.name.as_str()) else {
        return Vec::new();
    };
    match &site.kind {
        CallKind::Method => cands
            .iter()
            .copied()
            .filter(|&c| files[nodes[c].file].fns[nodes[c].item].impl_type.is_some())
            .collect(),
        CallKind::Path(segs) => {
            let caller_item = &files[caller.file].fns[caller.item];
            let qual: Option<String> = match segs.last().map(String::as_str) {
                Some("Self") | Some("self") => caller_item.impl_type.clone(),
                Some(q) => Some(q.to_string()),
                None => None,
            };
            let Some(q) = qual else {
                return cands.clone();
            };
            let matched: Vec<usize> = cands
                .iter()
                .copied()
                .filter(|&c| {
                    let f = &files[nodes[c].file].fns[nodes[c].item];
                    f.impl_type.as_deref() == Some(q.as_str())
                        || f.module.last().map(String::as_str) == Some(q.as_str())
                })
                .collect();
            if matched.is_empty() {
                cands.clone()
            } else {
                matched
            }
        }
        CallKind::Free => {
            let same_file: Vec<usize> =
                cands.iter().copied().filter(|&c| nodes[c].file == caller.file).collect();
            if same_file.is_empty() {
                cands.clone()
            } else {
                same_file
            }
        }
    }
}

// ---------------------------------------------------------------------
// Module graph
// ---------------------------------------------------------------------

/// Evidence for one module edge: (file, 1-based line) of its first use.
pub type Evidence = (String, u32);

pub struct ModuleGraph {
    /// Top-level `rust/src` modules present in the tree, sorted.
    pub modules: Vec<String>,
    /// `from → to → first evidence`, both ends in `modules`.
    pub edges: BTreeMap<String, BTreeMap<String, Evidence>>,
}

impl ModuleGraph {
    /// Build from the extracted files. `test_lines[f][l]` marks 1-based
    /// line `l+1` of file `f` as test code (inline `crate::` paths inside
    /// test regions are skipped, matching the `use`-decl test flag).
    pub fn build(files: &[&FileItems], test_lines: &[Vec<bool>]) -> ModuleGraph {
        let mut modules: BTreeSet<String> = BTreeSet::new();
        for f in files {
            if let Some(top) = top_module(&f.rel) {
                modules.insert(top);
            }
        }
        let mut edges: BTreeMap<String, BTreeMap<String, Evidence>> = BTreeMap::new();
        let mut add = |from: &str, to: &str, ev: Evidence| {
            if from != to {
                edges
                    .entry(from.to_string())
                    .or_default()
                    .entry(to.to_string())
                    .or_insert(ev);
            }
        };
        for (fi, f) in files.iter().enumerate() {
            let Some(own) = top_module(&f.rel) else {
                continue; // lib.rs / main.rs / out-of-tree: crate roots, exempt
            };
            let is_test_line = |line: u32| {
                test_lines
                    .get(fi)
                    .and_then(|v| v.get(line.saturating_sub(1) as usize))
                    .copied()
                    .unwrap_or(false)
            };
            // `use` declarations.
            for u in &f.uses {
                if u.is_test {
                    continue;
                }
                if let Some(to) = resolve_target(&u.segments, &f.rel, &modules) {
                    add(&own, &to, (f.rel.clone(), u.line));
                }
            }
            // Inline qualified paths: `crate::x::…` / `super::…` in code.
            for (j, t) in f.toks.iter().enumerate() {
                if t.kind != TokKind::Ident || (t.text != "crate" && t.text != "super") {
                    continue;
                }
                if !f.toks.get(j + 1).map(|n| n.is_punct("::")).unwrap_or(false) {
                    continue;
                }
                // Skip the path head of a `use` (already handled) — a use
                // keyword directly before, or before a brace group.
                if j > 0 && f.toks[j - 1].is_ident("use") {
                    continue;
                }
                if is_test_line(t.line) {
                    continue;
                }
                let mut segs: Vec<String> = vec![t.text.clone()];
                let mut k = j + 1;
                while f.toks.get(k).map(|p| p.is_punct("::")).unwrap_or(false) {
                    match f.toks.get(k + 1) {
                        Some(n) if n.kind == TokKind::Ident => {
                            segs.push(n.text.clone());
                            k += 2;
                        }
                        _ => break,
                    }
                }
                if let Some(to) = resolve_target(&segs, &f.rel, &modules) {
                    add(&own, &to, (f.rel.clone(), t.line));
                }
            }
        }
        ModuleGraph { modules: modules.into_iter().collect(), edges }
    }

    /// First dependency cycle among the edges, as a module path
    /// `a → b → a`, if any. Recursive DFS — module counts are tiny.
    pub fn find_cycle(&self) -> Option<Vec<String>> {
        fn dfs(
            m: &str,
            edges: &BTreeMap<String, BTreeMap<String, Evidence>>,
            color: &mut BTreeMap<String, u8>, // 1 = on stack, 2 = done
            path: &mut Vec<String>,
        ) -> Option<Vec<String>> {
            color.insert(m.to_string(), 1);
            path.push(m.to_string());
            if let Some(succ) = edges.get(m) {
                for next in succ.keys() {
                    match color.get(next).copied().unwrap_or(0) {
                        1 => {
                            // Back edge: the cycle is `path` from `next` on.
                            let from = path.iter().position(|x| x == next).unwrap_or(0);
                            let mut cyc: Vec<String> = path[from..].to_vec();
                            cyc.push(next.clone());
                            return Some(cyc);
                        }
                        0 => {
                            if let Some(c) = dfs(next, edges, color, path) {
                                return Some(c);
                            }
                        }
                        _ => {}
                    }
                }
            }
            path.pop();
            color.insert(m.to_string(), 2);
            None
        }
        let mut color: BTreeMap<String, u8> = BTreeMap::new();
        let mut path: Vec<String> = Vec::new();
        for m in &self.modules {
            if color.get(m).copied().unwrap_or(0) == 0 {
                if let Some(c) = dfs(m, &self.edges, &mut color, &mut path) {
                    return Some(c);
                }
            }
        }
        None
    }
}

/// Top-level module of a file under `rust/src` (None for crate roots).
pub fn top_module(rel: &str) -> Option<String> {
    let m = file_module(rel);
    match m.first().map(String::as_str) {
        None | Some("main") => None,
        Some(top) => Some(top.to_string()),
    }
}

/// Resolve a path's target top-level module, if it lands in a *different*
/// known module: `crate::tensor::ops` → `tensor`; `super::…` walks up from
/// the file's own module.
fn resolve_target(segs: &[String], rel: &str, known: &BTreeSet<String>) -> Option<String> {
    let mut base: Vec<String>;
    let mut rest: &[String] = segs;
    match segs.first().map(String::as_str) {
        Some("crate") => {
            base = Vec::new();
            rest = &segs[1..];
        }
        Some("super") => {
            base = file_module(rel);
            base.pop();
            rest = &segs[1..];
            while rest.first().map(String::as_str) == Some("super") {
                base.pop();
                rest = &rest[1..];
            }
        }
        Some("self") => {
            base = file_module(rel);
            rest = &segs[1..];
        }
        _ => return None, // std / vendored / relative-2015 paths
    }
    let full_head = base.first().cloned().or_else(|| rest.first().cloned())?;
    known.contains(&full_head).then_some(full_head)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::items::extract;

    fn extract_all(files: &[(&str, &str)]) -> Vec<FileItems> {
        files.iter().map(|(r, t)| extract(r, t)).collect()
    }

    fn find(files: &[&FileItems], g: &CallGraph, label: &str) -> usize {
        (0..g.nodes.len())
            .find(|&i| node_label(files, &g.nodes[i]) == label)
            .unwrap_or_else(|| panic!("no node {label}"))
    }

    #[test]
    fn free_and_method_calls_link() {
        let items = extract_all(&[(
            "rust/src/serve/engine.rs",
            "pub struct Engine;\nimpl Engine {\n  pub fn serve(&self) { helper(); self.step(); }\n  fn step(&self) {}\n}\nfn helper() { leaf(); }\nfn leaf() {}\nfn unrelated() {}",
        )]);
        let files: Vec<&FileItems> = items.iter().collect();
        let g = CallGraph::build(&files);
        let serve = find(&files, &g, "Engine::serve");
        let parent = g.reach(&[serve]);
        let leaf = find(&files, &g, "engine::leaf");
        let step = find(&files, &g, "Engine::step");
        let unrelated = find(&files, &g, "engine::unrelated");
        assert!(parent[leaf].is_some());
        assert!(parent[step].is_some());
        assert!(parent[unrelated].is_none());
        let chain = g.chain(&files, &parent, leaf);
        assert_eq!(chain, "Engine::serve → engine::helper → engine::leaf");
    }

    #[test]
    fn cross_file_path_calls_prefer_qualifier() {
        let items = extract_all(&[
            (
                "rust/src/serve/engine.rs",
                "pub fn run() { crate::tensor::ops::apply(); Store::get(); }\npub struct X;",
            ),
            ("rust/src/tensor/ops.rs", "pub fn apply() {}"),
            (
                "rust/src/model/store.rs",
                "pub struct Store;\nimpl Store { pub fn get() {} }\npub fn apply() {}",
            ),
        ]);
        let files: Vec<&FileItems> = items.iter().collect();
        let g = CallGraph::build(&files);
        let run = find(&files, &g, "engine::run");
        let parent = g.reach(&[run]);
        let ops_apply = find(&files, &g, "ops::apply");
        let store_apply = find(&files, &g, "store::apply");
        let get = find(&files, &g, "Store::get");
        assert!(parent[ops_apply].is_some(), "qualified path must match its module");
        assert!(parent[store_apply].is_none(), "qualifier excludes other modules");
        assert!(parent[get].is_some());
    }

    #[test]
    fn nested_fn_calls_not_attributed_to_parent() {
        let items = extract_all(&[(
            "rust/src/a.rs",
            "pub fn outer() { fn inner() { secret(); } inner(); }\nfn secret() {}",
        )]);
        let files: Vec<&FileItems> = items.iter().collect();
        let g = CallGraph::build(&files);
        let outer = find(&files, &g, "a::outer");
        let inner = find(&files, &g, "a::inner");
        let secret = find(&files, &g, "a::secret");
        assert!(g.edges[outer].contains(&inner));
        assert!(!g.edges[outer].contains(&secret));
        assert!(g.edges[inner].contains(&secret));
        // Still transitively reachable — through inner.
        let parent = g.reach(&[outer]);
        assert!(parent[secret].is_some());
    }

    #[test]
    fn module_graph_sees_uses_and_inline_paths() {
        let items = extract_all(&[
            (
                "rust/src/serve/engine.rs",
                "use crate::model::Model;\npub fn f() { crate::tensor::ops::apply(); }\n#[cfg(test)]\nmod tests { use crate::report::Summary; }",
            ),
            ("rust/src/model/mod.rs", "pub struct Model;"),
            ("rust/src/tensor/ops.rs", "pub fn apply() {}"),
            ("rust/src/report/mod.rs", "pub struct Summary;"),
        ]);
        let files: Vec<&FileItems> = items.iter().collect();
        // Test-line mask: mark the cfg(test) module lines of file 0.
        let mut masks: Vec<Vec<bool>> = items.iter().map(|_| vec![false; 64]).collect();
        for l in 2..5 {
            masks[0][l] = true; // lines 3..=5 (0-based idx 2..) are the test mod
        }
        let mg = ModuleGraph::build(&files, &masks);
        let serve = mg.edges.get("serve").expect("serve edges");
        assert!(serve.contains_key("model"));
        assert!(serve.contains_key("tensor"));
        assert!(!serve.contains_key("report"), "test-only use must not create an edge");
    }

    #[test]
    fn module_cycle_is_found() {
        let items = extract_all(&[
            ("rust/src/a/mod.rs", "use crate::b::X;"),
            ("rust/src/b/mod.rs", "use crate::c::Y;\npub struct X;"),
            ("rust/src/c/mod.rs", "use crate::a::Z;\npub struct Y;"),
        ]);
        let files: Vec<&FileItems> = items.iter().collect();
        let masks: Vec<Vec<bool>> = items.iter().map(|_| vec![false; 8]).collect();
        let mg = ModuleGraph::build(&files, &masks);
        let cyc = mg.find_cycle().expect("cycle");
        assert!(cyc.len() >= 3, "cycle {cyc:?}");
        assert_eq!(cyc.first(), cyc.last());
    }

    #[test]
    fn super_paths_resolve() {
        let items = extract_all(&[
            ("rust/src/model/forward.rs", "use super::store::Store;\nuse crate::quant::Q;"),
            ("rust/src/model/store.rs", "pub struct Store;"),
            ("rust/src/quant/mod.rs", "pub struct Q;"),
        ]);
        let files: Vec<&FileItems> = items.iter().collect();
        let masks: Vec<Vec<bool>> = items.iter().map(|_| vec![false; 8]).collect();
        let mg = ModuleGraph::build(&files, &masks);
        // super:: stays inside `model` (self-edge, dropped); crate::quant links.
        let model = mg.edges.get("model").expect("model edges");
        assert!(model.contains_key("quant"));
        assert_eq!(model.len(), 1);
    }
}
