"""AOT export: HLO text generation and manifest structure. Exports a small
subset (one model, small buckets) into a temp dir — the full export is
`make artifacts`."""

import json
import os
import tempfile

from compile import aot
from compile.configs import ModelConfig


def test_export_writes_hlo_and_manifest(monkeypatch):
    tiny = ModelConfig("tiny", 2, 16, 8, 4, 2, 1, 2, 64, 64)
    monkeypatch.setattr(aot, "SEQ_BUCKETS", [8])
    monkeypatch.setattr(aot, "TOK_BUCKETS", [8])
    with tempfile.TemporaryDirectory() as td:
        hlo_dir = os.path.join(td, "hlo")
        os.makedirs(hlo_dir)
        entries = []
        aot.export_model(tiny, hlo_dir, "hlo", entries)
        # 3 seq-bucket kinds + 2 tok-bucket kinds.
        kinds = sorted(e["kind"] for e in entries)
        assert kinds == sorted([
            "tiny/attention", "tiny/router", "tiny/lm_head",
            "tiny/expert_ffn", "tiny/expert_ffn_q",
        ])
        for e in entries:
            path = os.path.join(td, e["path"])
            assert os.path.exists(path)
            text = open(path).read()
            assert "HloModule" in text, "must be HLO text, not a proto"
            assert e["bucket_m"] == 8
        manifest = {"version": 1, "entries": entries}
        mpath = os.path.join(td, "manifest.json")
        json.dump(manifest, open(mpath, "w"))
        back = json.load(open(mpath))
        assert back["version"] == 1
        assert len(back["entries"]) == 5


def test_hlo_text_has_expected_shapes():
    tiny = ModelConfig("tiny", 2, 16, 8, 4, 2, 1, 2, 64, 64)
    text = aot.to_hlo_text(
        lambda x, w1, w2, w3: aot.expert_ffn_op(x, w1, w2, w3),
        (aot.spec(8, 16), aot.spec(16, 8), aot.spec(8, 16), aot.spec(16, 8)),
    )
    assert "f32[8,16]" in text
    assert "HloModule" in text
