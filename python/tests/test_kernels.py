"""L1 kernel correctness: every Pallas kernel vs its pure-jnp oracle,
swept over shapes/bit-widths with hypothesis. This is the CORE correctness
signal for the compile path."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.attention import attention
from compile.kernels.moe_ffn import moe_ffn, moe_ffn_q
from compile.kernels.quant_matmul import quant_matmul, quant_matmul4
from compile.kernels.router_topk import router, router_topk

RNG = np.random.default_rng(7)


def rand(*shape, scale=1.0):
    return jnp.array(RNG.normal(size=shape) * scale, dtype=jnp.float32)


def quantize_np(w, bits, group_size):
    """Group-wise asymmetric RTN (mirrors rust quant::quantizer)."""
    w = np.asarray(w)
    k, n = w.shape
    gs = min(group_size, k)
    ng = (k + gs - 1) // gs
    qmax = (1 << bits) - 1
    codes = np.zeros((k, n), np.uint8)
    scales = np.zeros((ng, n), np.float32)
    zeros = np.zeros((ng, n), np.float32)
    for g in range(ng):
        r0, r1 = g * gs, min((g + 1) * gs, k)
        mn = np.minimum(w[r0:r1].min(axis=0), 0)
        mx = np.maximum(w[r0:r1].max(axis=0), 0)
        s = np.maximum((mx - mn) / qmax, 1e-10)
        z = np.clip(np.round(-mn / s), 0, qmax)
        codes[r0:r1] = np.clip(np.round(w[r0:r1] / s + z), 0, qmax).astype(np.uint8)
        scales[g], zeros[g] = s, z
    return jnp.array(codes), jnp.array(scales), jnp.array(zeros)


# ---------------------------------------------------------------- quant_matmul

@settings(max_examples=12, deadline=None)
@given(
    m=st.sampled_from([8, 16, 32]),
    k=st.sampled_from([32, 64, 128]),
    n=st.sampled_from([16, 32]),
    bits=st.sampled_from([2, 3, 4, 8]),
)
def test_quant_matmul_matches_ref(m, k, n, bits):
    x = rand(m, k)
    w = rand(k, n, scale=0.5)
    gs = 32
    codes, scales, zeros = quantize_np(w, bits, gs)
    out = quant_matmul(x, codes, scales, zeros, group_size=gs, bm=8, bk=32, bn=16)
    want = ref.quant_matmul_ref(x, codes, scales, zeros, gs)
    np.testing.assert_allclose(out, want, rtol=1e-4, atol=1e-4)


def test_quant_matmul_multi_k_tiles():
    # K spanning several tiles exercises the k-loop accumulation.
    x = rand(16, 256)
    codes, scales, zeros = quantize_np(rand(256, 32, scale=0.3), 4, 64)
    out = quant_matmul(x, codes, scales, zeros, group_size=64, bm=16, bk=64, bn=32)
    want = ref.quant_matmul_ref(x, codes, scales, zeros, 64)
    np.testing.assert_allclose(out, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=8, deadline=None)
@given(k=st.sampled_from([32, 64]), n=st.sampled_from([8, 16]))
def test_quant_matmul4_packed(k, n):
    x = rand(8, k)
    codes, scales, zeros = quantize_np(rand(k, n, scale=0.5), 4, 16)
    packed = ref.pack4_ref(codes)
    out = quant_matmul4(x, packed, scales, zeros, group_size=16)
    want = ref.quant_matmul_ref(x, codes, scales, zeros, 16)
    np.testing.assert_allclose(out, want, rtol=1e-4, atol=1e-4)


def test_dequant_zero_code_is_min():
    # code 0 dequantizes to -zero*scale = group min (asymmetric property).
    w = rand(32, 4, scale=1.0)
    codes, scales, zeros = quantize_np(w, 3, 32)
    dq = ref.dequant_ref(codes, scales, zeros, 32)
    err = np.abs(np.asarray(dq) - np.asarray(w)).max()
    step = float(np.asarray(scales).max())
    assert err <= 0.5 * step + 1e-5


# ---------------------------------------------------------------- moe_ffn

@settings(max_examples=10, deadline=None)
@given(
    m=st.sampled_from([8, 16, 64]),
    d=st.sampled_from([16, 32, 128]),
    ff=st.sampled_from([8, 64]),
)
def test_moe_ffn_matches_ref(m, d, ff):
    x = rand(m, d)
    w1, w2, w3 = rand(d, ff, scale=0.2), rand(ff, d, scale=0.2), rand(d, ff, scale=0.2)
    out = moe_ffn(x, w1, w2, w3, bm=8)
    want = ref.moe_ffn_ref(x, w1, w2, w3)
    np.testing.assert_allclose(out, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=6, deadline=None)
@given(bits=st.sampled_from([2, 3, 4]), ff=st.sampled_from([24, 64]))
def test_moe_ffn_q_matches_dequantized_ref(bits, ff):
    d, m, gs = 32, 16, 16
    x = rand(m, d)
    w1, w2, w3 = rand(d, ff, scale=0.2), rand(ff, d, scale=0.2), rand(d, ff, scale=0.2)
    c1, s1, z1 = quantize_np(w1, bits, gs)
    c2, s2, z2 = quantize_np(w2, bits, gs)
    c3, s3, z3 = quantize_np(w3, bits, gs)
    out = moe_ffn_q(x, c1, s1, z1, c2, s2, z2, c3, s3, z3, group_size=gs, bm=8)
    want = ref.moe_ffn_ref(
        x,
        ref.dequant_ref(c1, s1, z1, gs),
        ref.dequant_ref(c2, s2, z2, gs),
        ref.dequant_ref(c3, s3, z3, gs),
    )
    np.testing.assert_allclose(out, want, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------- attention

@settings(max_examples=8, deadline=None)
@given(seq=st.sampled_from([4, 16, 64]), heads=st.sampled_from([1, 2, 4]))
def test_attention_matches_ref(seq, heads):
    d = 32
    x = rand(seq, d)
    ws = [rand(d, d, scale=0.2) for _ in range(4)]
    out = attention(x, *ws, n_heads=heads)
    want = ref.attention_ref(x, *ws, heads)
    np.testing.assert_allclose(out, want, rtol=1e-3, atol=1e-4)


def test_attention_causality():
    d = 16
    x1 = rand(8, d)
    x2 = jnp.concatenate([x1[:4], rand(4, d)])
    ws = [rand(d, d, scale=0.2) for _ in range(4)]
    a = attention(x1, *ws, n_heads=2)
    b = attention(x2, *ws, n_heads=2)
    np.testing.assert_allclose(a[:4], b[:4], rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------- router

@settings(max_examples=8, deadline=None)
@given(t=st.sampled_from([1, 8, 32]), e=st.sampled_from([8, 16, 64]))
def test_router_matches_ref(t, e):
    d = 32
    x = rand(t, d)
    w = rand(d, e, scale=0.3)
    logits, scores = router(x, w)
    lw, sw = ref.router_ref(x, w)
    np.testing.assert_allclose(logits, lw, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(scores, sw, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(scores).sum(-1), 1.0, rtol=1e-4)


def test_router_topk_selects_max():
    x = rand(16, 32)
    w = rand(32, 8, scale=0.3)
    _, scores, top_s, top_i = router_topk(x, w, 2)
    s = np.asarray(scores)
    for t in range(16):
        want = np.argsort(-s[t])[:2]
        assert set(np.asarray(top_i)[t].tolist()) == set(want.tolist())
        assert np.asarray(top_s)[t, 0] >= np.asarray(top_s)[t, 1]
