"""Datagen: determinism, region structure, and the Fig-2 premise
(intra-family similarity > inter-family) at the token-distribution level.
Includes the PCG64 cross-language golden values (verified against the rust
implementation — see rust/src/tensor/rng.rs)."""

import numpy as np

from compile.datagen import (DATASETS, FAMILY_SPAN, SHARED_TOKENS, VOCAB,
                             CorpusGen, Pcg64, WikiMixture)

# Golden values from rust: Pcg64::new(42, 7).next_u64() x3.
RUST_GOLDEN = [4550322480638507292, 14374554680213026787, 10648956799161994513]


def test_pcg_matches_rust_golden():
    r = Pcg64(42, 7)
    assert [r.next_u64() for _ in range(3)] == RUST_GOLDEN


def test_tokens_in_region():
    for name, fam, _ in DATASETS[:6]:
        seq = CorpusGen(name, 1).sequence(300)
        lo = SHARED_TOKENS + fam * FAMILY_SPAN
        hi = lo + FAMILY_SPAN
        for t in seq:
            assert t < VOCAB
            assert t < SHARED_TOKENS or (lo <= t < hi)


def test_deterministic():
    a = CorpusGen("piqa", 9).sequence(64)
    b = CorpusGen("piqa", 9).sequence(64)
    assert (a == b).all()
    c = CorpusGen("piqa", 10).sequence(64)
    assert not (a == c).all()


def _hist(tokens):
    h = np.bincount(tokens, minlength=VOCAB).astype(float)
    return h / h.sum()


def test_intra_vs_inter_family_similarity():
    cos = lambda a, b: float(a @ b / (np.linalg.norm(a) * np.linalg.norm(b)))
    hm = _hist(CorpusGen("gsm8k", 3).sequence(3000))
    hm2 = _hist(CorpusGen("mathqa", 4).sequence(3000))
    hc = _hist(CorpusGen("humaneval", 3).sequence(3000))
    assert cos(hm, hm2) > cos(hm, hc) + 0.2


def test_wiki_mixture_rotates_all_families():
    w = WikiMixture(2)
    seqs = [w.sequence(48) for _ in range(19)]
    fams = set()
    for s in seqs:
        for t in s:
            if t >= SHARED_TOKENS:
                fams.add((int(t) - SHARED_TOKENS) // FAMILY_SPAN)
    assert fams == {0, 1, 2, 3}
