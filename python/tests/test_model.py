"""L2 model correctness: forward shapes, causality, kernel-op/training-path
agreement, loss behaviour, weight IO round-trip."""

import os
import tempfile

import jax.numpy as jnp
import numpy as np

from compile import binio
from compile.configs import ZOO, ModelConfig
from compile.kernels import ref
from compile.model import (attention_op, expert_ffn_op, forward, init_params,
                           lm_loss, moe_block, params_to_tensorfile, router_op)

TINY = ModelConfig("tiny", 2, 16, 8, 4, 2, 1, 2, 64, 64)


def test_forward_shapes_and_finite():
    p = init_params(TINY, 0)
    tokens = jnp.arange(10) % 64
    logits, aux = forward(p, TINY, tokens)
    assert logits.shape == (10, 64)
    assert np.isfinite(np.asarray(logits)).all()
    assert float(aux) > 0


def test_forward_causality():
    p = init_params(TINY, 1)
    a, _ = forward(p, TINY, jnp.array([1, 2, 3, 4]))
    b, _ = forward(p, TINY, jnp.array([1, 2, 3, 60]))
    np.testing.assert_allclose(a[:3], b[:3], rtol=2e-3, atol=2e-4)
    assert np.abs(np.asarray(a[3]) - np.asarray(b[3])).max() > 1e-4


def test_moe_block_renormalizes_topk():
    # With top_k == n_experts the mix weights sum to 1 and all experts fire.
    p = init_params(TINY, 2)
    x = jnp.array(np.random.default_rng(0).normal(size=(6, 16)), jnp.float32)
    out, aux = moe_block(
        x, p["l0.router"], p["l0.experts_w1"], p["l0.experts_w2"],
        p["l0.experts_w3"], None, TINY.n_experts,
    )
    assert out.shape == (6, 16)
    assert np.isfinite(np.asarray(out)).all()
    # aux for fully-dense dispatch = top_k (sum_e me*de*E with de = k/E*E).
    assert abs(float(aux) - TINY.n_experts) < 1e-3


def test_loss_decreases_with_training_signal():
    # One gradient step on a repeated batch must reduce the loss.
    import jax
    p = init_params(TINY, 3)
    batch = jnp.tile(jnp.arange(32)[None, :] % 64, (2, 1))
    loss0, grads = jax.value_and_grad(lm_loss)(p, TINY, batch)
    p2 = jax.tree.map(lambda w, g: w - 0.1 * g, p, grads)
    loss1 = lm_loss(p2, TINY, batch)
    assert float(loss1) < float(loss0)


def test_kernel_ops_match_training_path():
    """The AOT kernel ops must agree with the pure-jnp ops the training
    forward uses — this ties L1 to L2."""
    rng = np.random.default_rng(5)
    d, ff, heads = 32, 16, 4
    x = jnp.array(rng.normal(size=(16, d)), jnp.float32)
    ws = [jnp.array(rng.normal(size=(d, d)) * 0.2, jnp.float32) for _ in range(4)]
    (a_kernel,) = attention_op(x, *ws, heads)
    a_ref = ref.attention_ref(x, *ws, heads)
    np.testing.assert_allclose(a_kernel, a_ref, rtol=1e-3, atol=1e-4)

    w1 = jnp.array(rng.normal(size=(d, ff)) * 0.2, jnp.float32)
    w2 = jnp.array(rng.normal(size=(ff, d)) * 0.2, jnp.float32)
    w3 = jnp.array(rng.normal(size=(d, ff)) * 0.2, jnp.float32)
    (y_kernel,) = expert_ffn_op(x, w1, w2, w3)
    np.testing.assert_allclose(y_kernel, ref.moe_ffn_ref(x, w1, w2, w3),
                               rtol=1e-4, atol=1e-4)

    wr = jnp.array(rng.normal(size=(d, 8)) * 0.2, jnp.float32)
    logits, scores = router_op(x, wr)
    lw, sw = ref.router_ref(x, wr)
    np.testing.assert_allclose(logits, lw, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(scores, sw, rtol=1e-4, atol=1e-5)


def test_tensorfile_roundtrip_and_layout():
    p = init_params(TINY, 4)
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "w.bin")
        params_to_tensorfile(p, TINY, path)
        back = binio.load(path)
    assert back["config"].tolist() == [2, 16, 8, 4, 2, 1, 2, 64, 64]
    np.testing.assert_allclose(back["embed"], np.asarray(p["embed"]), rtol=1e-6)
    np.testing.assert_allclose(
        back["layer1.expert3.w2"], np.asarray(p["l1.experts_w2"][3]), rtol=1e-6
    )
    np.testing.assert_allclose(
        back["layer0.shared0.w1"], np.asarray(p["l0.shared_w1"][0]), rtol=1e-6
    )
    assert back["layer0.router"].shape == (16, 4)


def test_zoo_configs_match_rust():
    ds = ZOO["deepseek-mini"]
    assert (ds.n_experts, ds.top_k, ds.n_shared) == (64, 6, 2)
    qw = ZOO["qwen-mini"]
    assert (qw.n_experts, qw.top_k, qw.n_shared) == (60, 4, 4)
    for cfg in ZOO.values():
        assert cfg.d_model % cfg.n_heads == 0
