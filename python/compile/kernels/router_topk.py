"""L1 Pallas kernel: MoE router — logits + softmax scores.

The router matmul is tiny (d_model × n_experts ≤ 128×64) but it sits on
the critical path of *every* MoE layer and, after PESF, of the pruning
decision itself, so it gets a fused kernel: one VMEM round-trip produces
both the logits (QESC's calibration target) and the softmax scores (the
selection distribution). Top-k itself stays in XLA (`jax.lax.top_k`) —
sorting networks are not MXU work.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _router_kernel(x_ref, w_ref, logits_ref, scores_ref):
    x = x_ref[...]
    logits = jnp.dot(x, w_ref[...], preferred_element_type=jnp.float32)
    logits_ref[...] = logits
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - m)
    scores_ref[...] = e / jnp.sum(e, axis=-1, keepdims=True)


@jax.jit
def router(x, w):
    """(tokens, d) @ (d, n_experts) -> (logits, softmax scores)."""
    t, d = x.shape
    n = w.shape[1]
    return pl.pallas_call(
        _router_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((t, n), jnp.float32),
            jax.ShapeDtypeStruct((t, n), jnp.float32),
        ),
        interpret=True,
    )(x, w)


def router_topk(x, w, k):
    """Convenience: logits, scores, and the top-k (scores, indices)."""
    logits, scores = router(x, w)
    top_s, top_i = jax.lax.top_k(scores, k)
    return logits, scores, top_s, top_i
