"""L1 Pallas kernel: fused SwiGLU expert FFN, fp and quantized variants.

One expert's whole weight set (d_model × d_ff × 3) fits in VMEM for the
mini models (128×256×3×4B = 384 KB ≪ 16 MB), so the kernel tiles only over
tokens (M): each grid step stages an (bm × d_model) activation tile and
computes (silu(x@w1) * (x@w3)) @ w2 entirely on-chip — one HBM round-trip
per token tile instead of three (the fusion BitBLAS/Ladder would do with
three separate GEMM launches).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _silu(x):
    return x * jax.nn.sigmoid(x)


def _moe_ffn_kernel(x_ref, w1_ref, w2_ref, w3_ref, o_ref):
    x = x_ref[...]
    a = jnp.dot(x, w1_ref[...], preferred_element_type=jnp.float32)
    b = jnp.dot(x, w3_ref[...], preferred_element_type=jnp.float32)
    h = _silu(a) * b
    o_ref[...] = jnp.dot(h, w2_ref[...], preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("bm",))
def moe_ffn(x, w1, w2, w3, *, bm=128):
    """(M, d) SwiGLU through one expert; grid over M tiles."""
    m, d = x.shape
    d_ff = w1.shape[1]
    bm = min(bm, m)
    assert m % bm == 0, (m, bm)
    grid = (m // bm,)
    return pl.pallas_call(
        _moe_ffn_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, d), lambda i: (i, 0)),
            pl.BlockSpec((d, d_ff), lambda i: (0, 0)),
            pl.BlockSpec((d_ff, d), lambda i: (0, 0)),
            pl.BlockSpec((d, d_ff), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, d), jnp.float32),
        interpret=True,
    )(x, w1, w2, w3)


def _moe_ffn_q_kernel(x_ref, c1_ref, s1_ref, z1_ref, c2_ref, s2_ref, z2_ref,
                      c3_ref, s3_ref, z3_ref, o_ref, *, gs_d, gs_ff):
    """Quantized variant: dequantize all three weight tiles in VMEM, then
    the same fused SwiGLU. This is the serving-path kernel: packed codes
    stream from HBM at `bits`/8 the bandwidth of f32 weights."""
    x = x_ref[...]

    def dq(c_ref, s_ref, z_ref, gs):
        codes = c_ref[...].astype(jnp.float32)
        gidx = jnp.arange(codes.shape[0]) // gs
        return (codes - z_ref[...][gidx]) * s_ref[...][gidx]

    w1 = dq(c1_ref, s1_ref, z1_ref, gs_d)   # rows = d_model
    w2 = dq(c2_ref, s2_ref, z2_ref, gs_ff)  # rows = d_ff
    w3 = dq(c3_ref, s3_ref, z3_ref, gs_d)
    a = jnp.dot(x, w1, preferred_element_type=jnp.float32)
    b = jnp.dot(x, w3, preferred_element_type=jnp.float32)
    h = _silu(a) * b
    o_ref[...] = jnp.dot(h, w2, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("group_size", "bm"))
def moe_ffn_q(x, c1, s1, z1, c2, s2, z2, c3, s3, z3, *, group_size=128, bm=128):
    """Quantized SwiGLU expert: codes (K, N) u8 + per-group scales/zeros."""
    m, d = x.shape
    d_ff = c1.shape[1]
    bm = min(bm, m)
    assert m % bm == 0
    gs_d = min(group_size, d)
    gs_ff = min(group_size, d_ff)
    g_d = (d + gs_d - 1) // gs_d
    g_ff = (d_ff + gs_ff - 1) // gs_ff
    grid = (m // bm,)
    full = lambda r, c: pl.BlockSpec((r, c), lambda i: (0, 0))
    return pl.pallas_call(
        functools.partial(_moe_ffn_q_kernel, gs_d=gs_d, gs_ff=gs_ff),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, d), lambda i: (i, 0)),
            full(d, d_ff), full(g_d, d_ff), full(g_d, d_ff),
            full(d_ff, d), full(g_ff, d), full(g_ff, d),
            full(d, d_ff), full(g_d, d_ff), full(g_d, d_ff),
        ],
        out_specs=pl.BlockSpec((bm, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, d), jnp.float32),
        interpret=True,
    )(x, c1, s1, z1, c2, s2, z2, c3, s3, z3)
