"""L1 Pallas kernel: causal multi-head self-attention.

Grid over heads; each step stages the full (seq × head_dim) Q/K/V panels in
VMEM (512 × 32 × 4B = 64 KB per panel) plus the (seq × seq) score tile
(512² × 4B = 1 MB) — comfortably inside VMEM for the mini models, so the
whole softmax(QKᵀ)·V runs on-chip without HBM spill. For longer sequences
this would become a flash-style K-block loop; at our max_seq the single
tile is both simpler and faster (no rescaling passes).

The causal mask uses broadcasted iotas (TPU needs ≥2-D iota).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attention_kernel(q_ref, k_ref, v_ref, o_ref, *, scale):
    q = q_ref[...][0]  # (seq, hd): leading head axis blocked to 1
    k = k_ref[...][0]
    v = v_ref[...][0]
    seq = q.shape[0]
    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    rows = jax.lax.broadcasted_iota(jnp.int32, (seq, seq), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (seq, seq), 1)
    scores = jnp.where(cols <= rows, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    o_ref[...] = jnp.dot(probs, v, preferred_element_type=jnp.float32)[None]


@functools.partial(jax.jit, static_argnames=("n_heads",))
def attention(x, wq, wk, wv, wo, *, n_heads):
    """Causal MHSA over (seq, d_model); matches ref.attention_ref."""
    seq, d = x.shape
    hd = d // n_heads
    q = (x @ wq).reshape(seq, n_heads, hd).transpose(1, 0, 2)  # (h, seq, hd)
    k = (x @ wk).reshape(seq, n_heads, hd).transpose(1, 0, 2)
    v = (x @ wv).reshape(seq, n_heads, hd).transpose(1, 0, 2)
    ctx = pl.pallas_call(
        functools.partial(_attention_kernel, scale=1.0 / (hd ** 0.5)),
        grid=(n_heads,),
        in_specs=[
            pl.BlockSpec((1, seq, hd), lambda h: (h, 0, 0)),
            pl.BlockSpec((1, seq, hd), lambda h: (h, 0, 0)),
            pl.BlockSpec((1, seq, hd), lambda h: (h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, seq, hd), lambda h: (h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_heads, seq, hd), jnp.float32),
        interpret=True,
    )(q, k, v)
    return ctx.transpose(1, 0, 2).reshape(seq, d) @ wo
