"""L1 Pallas kernel: fused group-wise dequantize + matmul.

This is the paper's BitBLAS role (§6.4 "Memory Saving and Inference
Efficiency") rethought for TPU (DESIGN.md §Hardware-Adaptation):

* CUDA BitBLAS stages packed weights through shared memory with warp-level
  `ldmatrix` fragments and dequantizes into tensor-core WMMA fragments.
* Here the HBM→VMEM schedule is expressed with `BlockSpec`s: each grid step
  owns an (bm × bk) X-tile and a (bk × bn) code-tile; the VPU dequantizes
  the code tile into a VMEM f32 tile ((code − zero) · scale) and the MXU
  consumes it via `jnp.dot(..., preferred_element_type=f32)`.
* Codes are packed along K (the reduction axis) exactly like rust
  `quant::pack::PackedMat`, so one VMEM tile unpacks from one contiguous
  byte run — the TPU analogue of BitBLAS packing along the warp-contiguous
  axis.

Two variants:
* `quant_matmul` — one byte per code (any bit-width ≤ 8). The storage
  compression happens at rest (rust PackedMat); this kernel fuses the
  dequant arithmetic with the GEMM.
* `quant_matmul4` — genuinely sub-byte: two 4-bit codes per byte, unpacked
  in-kernel with shift/mask on the VPU.

`interpret=True` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; real-TPU perf is estimated from the VMEM/MXU model in
DESIGN.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes: bm×bn accumulator (128×128×4B = 64 KB) + X tile
# (128×256×4B = 128 KB) + dequantized W tile (256×128×4B = 128 KB) stay far
# under the ~16 MB VMEM budget; bk=256 keeps the MXU fed in long runs.
BM, BK, BN = 128, 256, 128


def _cdiv(a, b):
    return (a + b - 1) // b


def _quant_matmul_kernel(x_ref, codes_ref, scales_ref, zeros_ref, o_ref, *,
                         group_size):
    """Grid: (m_tiles, n_tiles, k_tiles); k innermost, accumulating into the
    revisited output tile (the standard Pallas k-loop accumulation)."""
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]  # (bm, bk)
    codes = codes_ref[...].astype(jnp.float32)  # (bk, bn)
    # Per-row group index within this K tile (group_size divides bk).
    gidx = jnp.arange(codes.shape[0]) // group_size
    scale = scales_ref[...][gidx]  # (bk, bn)
    zero = zeros_ref[...][gidx]
    w = (codes - zero) * scale  # VPU dequant into VMEM
    o_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)  # MXU


@functools.partial(jax.jit, static_argnames=("group_size", "bm", "bk", "bn"))
def quant_matmul(x, codes, scales, zeros, *, group_size=128, bm=BM, bk=BK, bn=BN):
    """x (M, K) @ dequant(codes (K, N), scales/zeros (G, N)) -> (M, N).

    Requires group_size | bk | K and bm | M, bn | N (aot.py pads to buckets).
    """
    m, k = x.shape
    _, n = codes.shape
    bm, bk, bn = min(bm, m), min(bk, k), min(bn, n)
    gs = min(group_size, bk)
    assert k % bk == 0 and m % bm == 0 and n % bn == 0, (m, k, n, bm, bk, bn)
    assert bk % gs == 0
    nk = k // bk
    groups_per_bk = bk // gs
    grid = (m // bm, n // bn, nk)
    return pl.pallas_call(
        functools.partial(_quant_matmul_kernel, group_size=gs),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, ki: (i, ki)),
            pl.BlockSpec((bk, bn), lambda i, j, ki: (ki, j)),
            pl.BlockSpec((groups_per_bk, bn), lambda i, j, ki: (ki, j)),
            pl.BlockSpec((groups_per_bk, bn), lambda i, j, ki: (ki, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, ki: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, codes, scales, zeros)


def _quant_matmul4_kernel(x_ref, packed_ref, scales_ref, zeros_ref, o_ref, *, group_size):
    """Single-tile variant with in-kernel 4-bit unpack (two codes/byte)."""
    x = x_ref[...]  # (m, k)
    packed = packed_ref[...]  # (k//2, n) uint8
    lo = (packed & 0xF).astype(jnp.float32)
    hi = (packed >> 4).astype(jnp.float32)
    k = x.shape[1]
    n = packed.shape[1]
    codes = jnp.zeros((k, n), dtype=jnp.float32)
    codes = codes.at[0::2].set(lo).at[1::2].set(hi)
    gidx = jnp.arange(k) // group_size
    w = (codes - zeros_ref[...][gidx]) * scales_ref[...][gidx]
    o_ref[...] = jnp.dot(x, w, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("group_size",))
def quant_matmul4(x, packed, scales, zeros, *, group_size=128):
    """x (M, K) @ dequant(unpack4(packed (K//2, N))) -> (M, N), single tile."""
    m, k = x.shape
    n = packed.shape[1]
    gs = min(group_size, k)
    return pl.pallas_call(
        functools.partial(_quant_matmul4_kernel, group_size=gs),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, packed, scales, zeros)
