"""Pure-jnp reference oracles for every Pallas kernel (the correctness
ground truth pytest checks kernels against)."""

import jax
import jax.numpy as jnp


def dequant_ref(codes, scales, zeros, group_size):
    """Group-wise asymmetric dequantization.

    codes:  (K, N) uint8 integer codes
    scales: (G, N) f32 per-(group, column) scales, G = ceil(K / group_size)
    zeros:  (G, N) f32 zero points
    returns (K, N) f32 weights: (code - zero) * scale
    """
    k = codes.shape[0]
    gidx = jnp.arange(k) // group_size
    return (codes.astype(jnp.float32) - zeros[gidx]) * scales[gidx]


def quant_matmul_ref(x, codes, scales, zeros, group_size):
    """x @ dequant(codes): the fused dequant-matmul oracle."""
    w = dequant_ref(codes, scales, zeros, group_size)
    return x @ w


def pack4_ref(codes):
    """Pack 4-bit codes (K, N) into (K//2, N) bytes: two codes per byte,
    low nibble = even row (K-axis packing, matching rust PackedMat)."""
    lo = codes[0::2].astype(jnp.uint8)
    hi = codes[1::2].astype(jnp.uint8)
    return lo | (hi << 4)


def quant_matmul4_ref(x, packed, scales, zeros, group_size):
    """x @ dequant(unpack4(packed))."""
    lo = packed & 0xF
    hi = packed >> 4
    k2 = packed.shape[0]
    codes = jnp.zeros((k2 * 2, packed.shape[1]), dtype=jnp.uint8)
    codes = codes.at[0::2].set(lo).at[1::2].set(hi)
    return quant_matmul_ref(x, codes, scales, zeros, group_size)


def silu(x):
    return x * jax.nn.sigmoid(x)


def moe_ffn_ref(x, w1, w2, w3):
    """SwiGLU expert FFN: (silu(x@w1) * (x@w3)) @ w2."""
    return (silu(x @ w1) * (x @ w3)) @ w2


def attention_ref(x, wq, wk, wv, wo, n_heads):
    """Causal multi-head self-attention (matches rust model::forward)."""
    seq, d = x.shape
    hd = d // n_heads
    q = (x @ wq).reshape(seq, n_heads, hd)
    k = (x @ wk).reshape(seq, n_heads, hd)
    v = (x @ wv).reshape(seq, n_heads, hd)
    scores = jnp.einsum("ihd,jhd->hij", q, k) / jnp.sqrt(hd).astype(x.dtype)
    mask = jnp.tril(jnp.ones((seq, seq), dtype=bool))
    scores = jnp.where(mask[None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("hij,jhd->ihd", probs, v).reshape(seq, d)
    return ctx @ wo


def router_ref(x, w):
    """Router logits + softmax scores."""
    logits = x @ w
    return logits, jax.nn.softmax(logits, axis=-1)


def rmsnorm_ref(x, gain, eps=1e-6):
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x / jnp.sqrt(ms + eps) * gain
