"""Pretrain the four miniature MoE models on the synthetic task-mixture
corpus and save weights to artifacts/models/<name>.bin.

This is a *real* training loop (Adam, LM loss, Switch-style load-balance
aux) — the point is to induce the routing structure the paper's analysis
depends on: expert specialization over the task-typed token regions, which
yields (a) task-dependent expert-selection preferences (Fig 2), (b) ES
sparsity (A.11), and (c) a model whose PPL/accuracy degrade measurably
under low-bit quantization and recover under QESC calibration.

Usage: python -m compile.pretrain [--models a,b] [--steps N] [--out DIR]
Env:   EAC_PRETRAIN_STEPS overrides the step count (CI uses a small value).
"""

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from .configs import ZOO
from .datagen import WikiMixture
from .model import init_params, lm_loss, params_to_tensorfile

BATCH = 8
SEQ = 96
LR = 3e-3
WARMUP = 20


def adam_init(params):
    z = lambda: jax.tree.map(jnp.zeros_like, params)
    return {"m": z(), "v": z(), "t": 0}


def adam_step(params, grads, st, lr, b1=0.9, b2=0.98, eps=1e-9):
    st = {"m": jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, st["m"], grads),
          "v": jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, st["v"], grads),
          "t": st["t"] + 1}
    t = st["t"]
    corr = jnp.sqrt(1 - b2 ** t) / (1 - b1 ** t)
    params = jax.tree.map(
        lambda p, m, v: p - lr * corr * m / (jnp.sqrt(v) + eps), params, st["m"], st["v"]
    )
    return params, st


def pretrain(name, steps, seed=0, log_every=50, init_path=None):
    cfg = ZOO[name]
    if init_path and os.path.exists(init_path):
        from .model import tensorfile_to_params
        params = tensorfile_to_params(init_path, cfg)
        print(f"[{name}] continuing from {init_path}", flush=True)
    else:
        params = init_params(cfg, seed + 17)
    opt = adam_init(params)
    mix = WikiMixture(seed + 1)

    @jax.jit
    def step_fn(params, opt_m, opt_v, opt_t, batch, lr):
        st = {"m": opt_m, "v": opt_v, "t": opt_t}
        loss, grads = jax.value_and_grad(lm_loss)(params, cfg, batch)
        params, st = adam_step(params, grads, st, lr)
        return params, st["m"], st["v"], st["t"], loss

    opt_m, opt_v, opt_t = opt["m"], opt["v"], opt["t"]
    losses = []
    t0 = time.time()
    for s in range(steps):
        batch = jnp.asarray(mix.batch(BATCH, SEQ), dtype=jnp.int32)
        lr = LR * min(1.0, (s + 1) / WARMUP)
        params, opt_m, opt_v, opt_t, loss = step_fn(params, opt_m, opt_v, opt_t, batch, lr)
        losses.append(float(loss))
        if s % log_every == 0 or s == steps - 1:
            print(f"[{name}] step {s:4d} loss {float(loss):.4f} "
                  f"({time.time() - t0:.0f}s)", flush=True)
    return params, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--models", default=",".join(ZOO))
    ap.add_argument("--steps", type=int,
                    default=int(os.environ.get("EAC_PRETRAIN_STEPS", "300")))
    ap.add_argument("--out", default="../artifacts/models")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--continue-from-saved", action="store_true",
                    help="resume each model from its existing .bin")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    summary = []
    for name in args.models.split(","):
        name = name.strip()
        t0 = time.time()
        init = os.path.join(args.out, f"{name}.bin") if args.continue_from_saved else None
        params, losses = pretrain(name, args.steps, seed=args.seed + 1, init_path=init)
        path = os.path.join(args.out, f"{name}.bin")
        params_to_tensorfile(params, ZOO[name], path)
        first = float(np.mean(losses[:10]))
        last = float(np.mean(losses[-10:]))
        summary.append((name, first, last, time.time() - t0))
        print(f"[{name}] saved {path}: loss {first:.3f} -> {last:.3f} "
              f"in {time.time() - t0:.0f}s", flush=True)
    # Loss-curve record for EXPERIMENTS.md.
    with open(os.path.join(args.out, "pretrain_log.txt"), "w") as f:
        for name, first, last, secs in summary:
            f.write(f"{name}: loss {first:.4f} -> {last:.4f} ({secs:.0f}s, "
                    f"{args.steps} steps, batch {BATCH}x{SEQ})\n")


if __name__ == "__main__":
    main()
