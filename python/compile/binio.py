"""TensorFile binary format — the Python half of rust `util::binio`.

Layout (little-endian):
  magic(u32=0x454d4f45) version(u32=1) n_entries(u32)
  entry := name_len(u32) name dtype(u32: 0=f32,1=u32,2=u8) ndim(u32)
           dims(u64*ndim) payload
Entries are written sorted by name (rust reads into a BTreeMap; sorting
keeps byte-identical round-trips).
"""

import struct

import numpy as np

MAGIC = 0x454D4F45
VERSION = 1
_DTYPES = {0: np.float32, 1: np.uint32, 2: np.uint8}
_CODES = {np.dtype(np.float32): 0, np.dtype(np.uint32): 1, np.dtype(np.uint8): 2}


def save(path, tensors):
    """tensors: dict name -> np.ndarray (f32/u32/u8)."""
    with open(path, "wb") as f:
        f.write(struct.pack("<III", MAGIC, VERSION, len(tensors)))
        for name in sorted(tensors):
            arr = np.ascontiguousarray(tensors[name])
            code = _CODES[arr.dtype]
            nb = name.encode("utf-8")
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<II", code, arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<Q", d))
            f.write(arr.tobytes())


def load(path):
    """Returns dict name -> np.ndarray."""
    out = {}
    with open(path, "rb") as f:
        magic, version, n = struct.unpack("<III", f.read(12))
        assert magic == MAGIC, "bad magic"
        assert version == VERSION, f"unsupported version {version}"
        for _ in range(n):
            (name_len,) = struct.unpack("<I", f.read(4))
            name = f.read(name_len).decode("utf-8")
            code, ndim = struct.unpack("<II", f.read(8))
            dims = [struct.unpack("<Q", f.read(8))[0] for _ in range(ndim)]
            dt = _DTYPES[code]
            count = int(np.prod(dims)) if dims else 1
            arr = np.frombuffer(f.read(count * np.dtype(dt).itemsize), dtype=dt)
            out[name] = arr.reshape(dims).copy()
    return out
