"""L2 — the MoE transformer in JAX.

Two forward paths over the same parameters:

* `forward` — the *training/eval* path: pure-jnp ops (dense expert compute,
  differentiable top-k routing via renormalized softmax weights). Used by
  `pretrain.py`.
* kernel ops (`attention_op`, `expert_ffn_op`, `expert_ffn_q_op`,
  `router_op`, `lm_head_op`) — the *AOT* path: thin wrappers over the L1
  Pallas kernels, lowered per-bucket by `aot.py` into the HLO artifacts the
  Rust runtime executes. pytest asserts both paths agree.

Parameter naming matches rust `model::weights` (layer{i}.wq, .expert{e}.w1,
…) so TensorFiles round-trip between the two stacks.
"""

import jax
import jax.numpy as jnp
import numpy as np

from . import binio
from .configs import ModelConfig
from .kernels import ref
from .kernels.attention import attention as attention_kernel
from .kernels.moe_ffn import moe_ffn as moe_ffn_kernel
from .kernels.moe_ffn import moe_ffn_q as moe_ffn_q_kernel
from .kernels.router_topk import router as router_kernel


# ---------------------------------------------------------------- params

def init_params(cfg: ModelConfig, seed: int):
    """Random init, stacked expert weights: experts_w1 (E, d, ff) etc."""
    k = jax.random.PRNGKey(seed)
    keys = iter(jax.random.split(k, 2 + cfg.n_layers * 12))
    sd = 1.0 / np.sqrt(cfg.d_model)
    sf = np.sqrt(2.0 / cfg.d_model)
    sb = np.sqrt(2.0 / cfg.d_ff)
    p = {
        "embed": jax.random.normal(next(keys), (cfg.vocab, cfg.d_model)) * sd,
        "final_norm": jnp.ones(cfg.d_model),
    }
    for i in range(cfg.n_layers):
        p[f"l{i}.attn_norm"] = jnp.ones(cfg.d_model)
        p[f"l{i}.ffn_norm"] = jnp.ones(cfg.d_model)
        for nm in ("wq", "wk", "wv", "wo"):
            p[f"l{i}.{nm}"] = jax.random.normal(next(keys), (cfg.d_model, cfg.d_model)) * sd
        p[f"l{i}.router"] = jax.random.normal(next(keys), (cfg.d_model, cfg.n_experts)) * sd
        e = cfg.n_experts
        p[f"l{i}.experts_w1"] = jax.random.normal(next(keys), (e, cfg.d_model, cfg.d_ff)) * sf
        p[f"l{i}.experts_w2"] = jax.random.normal(next(keys), (e, cfg.d_ff, cfg.d_model)) * sb
        p[f"l{i}.experts_w3"] = jax.random.normal(next(keys), (e, cfg.d_model, cfg.d_ff)) * sf
        if cfg.n_shared:
            s = cfg.n_shared
            p[f"l{i}.shared_w1"] = jax.random.normal(next(keys), (s, cfg.d_model, cfg.d_ff)) * sf
            p[f"l{i}.shared_w2"] = jax.random.normal(next(keys), (s, cfg.d_ff, cfg.d_model)) * sb
            p[f"l{i}.shared_w3"] = jax.random.normal(next(keys), (s, cfg.d_model, cfg.d_ff)) * sf
    return p


# ---------------------------------------------------------------- training forward

def moe_block(x, router_w, w1, w2, w3, shared, top_k):
    """Dense-compute MoE with renormalized top-k mixing (differentiable).

    x: (T, d); w1/w3: (E, d, ff); w2: (E, ff, d).
    Returns (out (T, d), aux) where aux carries load-balance statistics.
    """
    logits = x @ router_w  # (T, E)
    scores = jax.nn.softmax(logits, axis=-1)
    top_s, top_i = jax.lax.top_k(scores, top_k)  # (T, k)
    denom = jnp.sum(top_s, axis=-1, keepdims=True)
    mix = top_s / jnp.maximum(denom, 1e-9)  # renormalized weights (Eq. 2)
    # Dense expert outputs: (T, E, d). Fine at mini scale; the serving path
    # (rust) does the sparse gather/scatter version.
    h = ref.silu(jnp.einsum("td,edf->tef", x, w1)) * jnp.einsum("td,edf->tef", x, w3)
    outs = jnp.einsum("tef,efd->ted", h, w2)
    mask = jax.nn.one_hot(top_i, scores.shape[-1])  # (T, k, E)
    weights = jnp.einsum("tk,tke->te", mix, mask)  # (T, E)
    out = jnp.einsum("te,ted->td", weights, outs)
    if shared is not None:
        sw1, sw2, sw3 = shared
        hs = ref.silu(jnp.einsum("td,sdf->tsf", x, sw1)) * jnp.einsum("td,sdf->tsf", x, sw3)
        out = out + jnp.einsum("tsf,sfd->td", hs, sw2)
    # Load-balance aux (Switch-style): mean prob * mean dispatch per expert.
    me = jnp.mean(scores, axis=0)
    de = jnp.mean(jnp.sum(mask, axis=1), axis=0)
    aux = jnp.sum(me * de) * scores.shape[-1]
    return out, aux


def forward(params, cfg: ModelConfig, tokens):
    """Training/eval forward for one sequence (T,) -> logits (T, vocab)."""
    x = params["embed"][tokens]
    aux_total = 0.0
    for i in range(cfg.n_layers):
        xn = ref.rmsnorm_ref(x, params[f"l{i}.attn_norm"])
        x = x + ref.attention_ref(
            xn, params[f"l{i}.wq"], params[f"l{i}.wk"], params[f"l{i}.wv"],
            params[f"l{i}.wo"], cfg.n_heads,
        )
        xn = ref.rmsnorm_ref(x, params[f"l{i}.ffn_norm"])
        shared = (
            (params[f"l{i}.shared_w1"], params[f"l{i}.shared_w2"], params[f"l{i}.shared_w3"])
            if cfg.n_shared
            else None
        )
        moe, aux = moe_block(
            xn, params[f"l{i}.router"], params[f"l{i}.experts_w1"],
            params[f"l{i}.experts_w2"], params[f"l{i}.experts_w3"], shared, cfg.top_k,
        )
        x = x + moe
        aux_total = aux_total + aux
    xn = ref.rmsnorm_ref(x, params["final_norm"])
    return xn @ params["embed"].T, aux_total / cfg.n_layers


def lm_loss(params, cfg: ModelConfig, batch, aux_weight=0.01):
    """Mean next-token NLL + load-balance aux over a (B, T) batch."""

    def one(tokens):
        logits, aux = forward(params, cfg, tokens)
        lp = jax.nn.log_softmax(logits[:-1], axis=-1)
        nll = -jnp.take_along_axis(lp, tokens[1:, None].astype(jnp.int32), axis=-1).mean()
        return nll, aux

    nll, aux = jax.vmap(one)(batch)
    return nll.mean() + aux_weight * aux.mean()


# ---------------------------------------------------------------- AOT kernel ops

def attention_op(x, wq, wk, wv, wo, n_heads):
    """Bucketed causal attention op (L1 kernel) — lowered by aot.py."""
    return (attention_kernel(x, wq, wk, wv, wo, n_heads=n_heads),)


def expert_ffn_op(x, w1, w2, w3):
    """One expert over a token bucket (L1 kernel)."""
    return (moe_ffn_kernel(x, w1, w2, w3),)


def expert_ffn_q_op(x, c1, s1, z1, c2, s2, z2, c3, s3, z3, group_size):
    """Quantized expert over a token bucket (L1 kernel, u8 codes)."""
    return (moe_ffn_q_kernel(x, c1, s1, z1, c2, s2, z2, c3, s3, z3,
                             group_size=group_size),)


def router_op(x, w):
    """Router logits + scores (L1 kernel)."""
    logits, scores = router_kernel(x, w)
    return logits, scores


def lm_head_op(x, embed):
    """Tied-embedding output head (plain XLA GEMM: MXU-bound already)."""
    return (x @ embed.T,)


# ---------------------------------------------------------------- weight IO

def tensorfile_to_params(path, cfg: ModelConfig):
    """Inverse of params_to_tensorfile (restacks experts)."""
    t = binio.load(path)
    p = {
        "embed": jnp.asarray(t["embed"]),
        "final_norm": jnp.asarray(t["final_norm"]),
    }
    for i in range(cfg.n_layers):
        p[f"l{i}.attn_norm"] = jnp.asarray(t[f"layer{i}.attn_norm"])
        p[f"l{i}.ffn_norm"] = jnp.asarray(t[f"layer{i}.ffn_norm"])
        for nm in ("wq", "wk", "wv", "wo", "router"):
            p[f"l{i}.{nm}"] = jnp.asarray(t[f"layer{i}.{nm}"])
        for w in ("w1", "w2", "w3"):
            p[f"l{i}.experts_{w}"] = jnp.stack(
                [jnp.asarray(t[f"layer{i}.expert{e}.{w}"]) for e in range(cfg.n_experts)]
            )
            if cfg.n_shared:
                p[f"l{i}.shared_{w}"] = jnp.stack(
                    [jnp.asarray(t[f"layer{i}.shared{s}.{w}"]) for s in range(cfg.n_shared)]
                )
    return p


def params_to_tensorfile(params, cfg: ModelConfig, path):
    """Save in the rust `model::weights` layout (unstacked experts)."""
    t = {
        "config": np.array(
            [cfg.n_layers, cfg.d_model, cfg.d_ff, cfg.n_experts, cfg.top_k,
             cfg.n_shared, cfg.n_heads, cfg.vocab, cfg.max_seq],
            dtype=np.uint32,
        ),
        "embed": np.asarray(params["embed"], dtype=np.float32),
        "final_norm": np.asarray(params["final_norm"], dtype=np.float32),
    }
    for i in range(cfg.n_layers):
        t[f"layer{i}.attn_norm"] = np.asarray(params[f"l{i}.attn_norm"], np.float32)
        t[f"layer{i}.ffn_norm"] = np.asarray(params[f"l{i}.ffn_norm"], np.float32)
        for nm in ("wq", "wk", "wv", "wo", "router"):
            t[f"layer{i}.{nm}"] = np.asarray(params[f"l{i}.{nm}"], np.float32)
        for e in range(cfg.n_experts):
            t[f"layer{i}.expert{e}.w1"] = np.asarray(params[f"l{i}.experts_w1"][e], np.float32)
            t[f"layer{i}.expert{e}.w2"] = np.asarray(params[f"l{i}.experts_w2"][e], np.float32)
            t[f"layer{i}.expert{e}.w3"] = np.asarray(params[f"l{i}.experts_w3"][e], np.float32)
        for s in range(cfg.n_shared):
            t[f"layer{i}.shared{s}.w1"] = np.asarray(params[f"l{i}.shared_w1"][s], np.float32)
            t[f"layer{i}.shared{s}.w2"] = np.asarray(params[f"l{i}.shared_w2"][s], np.float32)
            t[f"layer{i}.shared{s}.w3"] = np.asarray(params[f"l{i}.shared_w3"][s], np.float32)
    binio.save(path, t)
