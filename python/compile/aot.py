"""AOT export: lower the L1/L2 kernel ops to HLO **text** for the Rust
PJRT runtime, and write artifacts/manifest.json.

HLO text — NOT `lowered.compile()` / `.serialize()` — is the interchange
format: the xla crate's xla_extension 0.5.1 rejects jax>=0.5 serialized
HloModuleProtos (64-bit instruction ids); the text parser reassigns ids
(see /opt/xla-example/README.md and aot_recipe.md).

Artifacts, per zoo model:
  attention.m{B}    x(B,d) wq wk wv wo -> ctx(B,d)      B in SEQ_BUCKETS
  expert_ffn.m{B}   x(B,d) w1 w2 w3 -> y(B,d)           B in TOK_BUCKETS
  expert_ffn_q.m{B} x(B,d) codes+scales+zeros x3 -> y   B in TOK_BUCKETS
  router.m{B}       x(B,d) w -> logits, scores          B in SEQ_BUCKETS
  lm_head.m{B}      x(B,d) embed -> logits(B,V)         B in SEQ_BUCKETS

Rust pads token counts up to the next bucket (runtime::client::executable_for).
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .configs import ZOO
from .model import (attention_op, expert_ffn_op, expert_ffn_q_op, lm_head_op,
                    router_op)

SEQ_BUCKETS = [32, 128, 512]
TOK_BUCKETS = [16, 64, 256, 1024]
GROUP_SIZE = 128


def to_hlo_text(fn, example_args):
    """Lower a jax fn to HLO text via stablehlo -> XlaComputation."""
    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def spec_u8(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.uint8)


def export_model(cfg, hlo_dir, rel_dir, entries):
    d, ff, v = cfg.d_model, cfg.d_ff, cfg.vocab
    g_d = (d + GROUP_SIZE - 1) // GROUP_SIZE
    gs_ff = min(GROUP_SIZE, ff)
    g_ff = (ff + gs_ff - 1) // gs_ff

    def emit(name, kind, bucket, fn, args, outputs):
        text = to_hlo_text(fn, args)
        fname = f"{cfg.name}_{kind}_m{bucket}.hlo.txt"
        with open(os.path.join(hlo_dir, fname), "w") as f:
            f.write(text)
        entries.append({
            "name": name,
            "path": f"{rel_dir}/{fname}",
            "kind": f"{cfg.name}/{kind}",
            "bucket_m": bucket,
            "inputs": [list(a.shape) for a in args],
            "outputs": [list(o) for o in outputs],
        })

    for b in SEQ_BUCKETS:
        if b > cfg.max_seq:
            continue
        emit(f"{cfg.name}.attention.m{b}", "attention", b,
             lambda x, wq, wk, wv, wo: attention_op(x, wq, wk, wv, wo, cfg.n_heads),
             (spec(b, d), spec(d, d), spec(d, d), spec(d, d), spec(d, d)),
             [(b, d)])
        emit(f"{cfg.name}.router.m{b}", "router", b,
             router_op,
             (spec(b, d), spec(d, cfg.n_experts)),
             [(b, cfg.n_experts), (b, cfg.n_experts)])
        emit(f"{cfg.name}.lm_head.m{b}", "lm_head", b,
             lm_head_op,
             (spec(b, d), spec(v, d)),
             [(b, v)])
    for b in TOK_BUCKETS:
        emit(f"{cfg.name}.expert_ffn.m{b}", "expert_ffn", b,
             expert_ffn_op,
             (spec(b, d), spec(d, ff), spec(ff, d), spec(d, ff)),
             [(b, d)])
        emit(f"{cfg.name}.expert_ffn_q.m{b}", "expert_ffn_q", b,
             lambda x, c1, s1, z1, c2, s2, z2, c3, s3, z3: expert_ffn_q_op(
                 x, c1, s1, z1, c2, s2, z2, c3, s3, z3, GROUP_SIZE),
             (spec(b, d),
              spec_u8(d, ff), spec(g_d, ff), spec(g_d, ff),
              spec_u8(ff, d), spec(g_ff, d), spec(g_ff, d),
              spec_u8(d, ff), spec(g_d, ff), spec(g_d, ff)),
             [(b, d)])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", default=",".join(ZOO))
    args = ap.parse_args()
    hlo_dir = os.path.join(args.out, "hlo")
    os.makedirs(hlo_dir, exist_ok=True)
    entries = []
    for name in args.models.split(","):
        cfg = ZOO[name.strip()]
        print(f"lowering {cfg.name} ...", flush=True)
        export_model(cfg, hlo_dir, "hlo", entries)
    manifest = {"version": 1, "entries": entries}
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(entries)} artifacts + manifest to {args.out}")


if __name__ == "__main__":
    main()
