"""Synthetic task-typed corpus — Python port of rust `data::corpus`.

Same construction, same constants, same PCG64 streams: four task families
own disjoint content-token regions with family-specific Markov dynamics;
datasets within a family share the family prior. The port matches the Rust
implementation at the *distribution* level (cross-language golden tests
compare token histograms, not exact streams: the Rust transition weights
are computed in f32, Python in f64, so individual draws may diverge after
many steps; the pretraining only needs the distribution).
"""

import numpy as np

VOCAB = 512
SHARED_TOKENS = 64
FAMILY_SPAN = 112
N_STATES = 12
P_SHARED = 0.25

FAMILIES = ["QA/CR", "Math", "Code", "French"]

# (name, family_index, variant) — mirrors rust data::corpus::DATASETS.
DATASETS = [
    ("winogrande", 0, 0), ("piqa", 0, 1), ("arc-challenge", 0, 2),
    ("boolq", 0, 3), ("hellaswag", 0, 4), ("social-iqa", 0, 5),
    ("openbookqa", 0, 6),
    ("gsm8k", 1, 0), ("mathqa", 1, 1), ("minerva-math", 1, 2),
    ("hendrycks-math", 1, 3),
    ("humaneval", 2, 0), ("mbpp", 2, 1), ("apps", 2, 2), ("conala", 2, 3),
    ("lambada-fr", 3, 0), ("xnli-fr", 3, 1), ("paws-fr", 3, 2),
    ("arc-fr", 3, 3),
]

_PCG_MULT = 0x2360ED051FC65DA44385DF649FCCF645
_MASK128 = (1 << 128) - 1
_MASK64 = (1 << 64) - 1


class Pcg64:
    """PCG-XSL-RR 128/64 — bit-exact port of rust tensor::rng::Pcg64."""

    def __init__(self, seed, stream):
        self.inc = ((((stream << 64) | 0xDA3E39CB94B95BDB) << 1) | 1) & _MASK128
        self.state = 0
        self.state = (self.state * _PCG_MULT + self.inc) & _MASK128
        self.state = (self.state + seed) & _MASK128
        self.state = (self.state * _PCG_MULT + self.inc) & _MASK128

    def next_u64(self):
        self.state = (self.state * _PCG_MULT + self.inc) & _MASK128
        rot = self.state >> 122
        xored = ((self.state >> 64) ^ self.state) & _MASK64
        if rot == 0:
            return xored
        return ((xored >> rot) | (xored << (64 - rot))) & _MASK64

    def next_f64(self):
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def next_f32(self):
        return np.float32((self.next_u64() >> 40) * np.float32(1.0 / (1 << 24)))

    def below(self, n):
        # Lemire's method, matching the rust implementation.
        x = self.next_u64()
        m = x * n
        l = m & _MASK64
        if l < n:
            t = (-n) % n
            while l < t:
                x = self.next_u64()
                m = x * n
                l = m & _MASK64
        return m >> 64

    def sample_weighted(self, weights):
        total = float(np.sum(np.maximum(weights, 0.0), dtype=np.float64))
        if total <= 0.0:
            return int(self.below(max(len(weights), 1)))
        t = self.next_f64() * total
        for i, w in enumerate(weights):
            t -= max(float(w), 0.0)
            if t <= 0.0:
                return i
        return len(weights) - 1


class CorpusGen:
    """Port of rust data::corpus::CorpusGen (same seeding scheme)."""

    def __init__(self, name, seed):
        spec = next(d for d in DATASETS if d[0] == name)
        _, f, variant = spec
        family_rng = Pcg64(9000 + f, 1)
        self.family_base = SHARED_TOKENS + f * FAMILY_SPAN
        centers = [int(family_rng.below(FAMILY_SPAN)) for _ in range(N_STATES)]
        ds_rng = Pcg64(9100 + f * 97 + variant, 2)
        for _ in range(2):
            i = int(ds_rng.below(N_STATES))
            centers[i] = int(ds_rng.below(FAMILY_SPAN))
        trans = np.zeros((N_STATES, N_STATES), dtype=np.float32)
        for i in range(N_STATES):
            for j in range(N_STATES):
                base = family_rng.next_f32()
                noise = np.float32(0.3) * ds_rng.next_f32()
                sticky = np.float32(1.5) if i == j else np.float32(0.0)
                trans[i, j] = max(base + noise + sticky, np.float32(1e-3))
            trans[i] /= trans[i].sum()
        self.centers = centers
        self.trans = trans
        self.state = 0
        self.rng = Pcg64(seed, 1000 + f * 31 + variant)

    def next_token(self):
        self.state = self.rng.sample_weighted(self.trans[self.state])
        if self.rng.next_f64() < P_SHARED:
            return int(self.rng.below(SHARED_TOKENS))
        center = self.centers[self.state]
        jitter = int(self.rng.below(9)) - 4
        pos = (center + jitter) % FAMILY_SPAN
        return self.family_base + pos

    def sequence(self, length):
        return np.array([self.next_token() for _ in range(length)], dtype=np.uint32)


class WikiMixture:
    """Balanced rotation through all 19 datasets (WikiText2's role)."""

    def __init__(self, seed):
        self.gens = [CorpusGen(d[0], seed) for d in DATASETS]
        self.next_idx = 0

    def sequence(self, length):
        g = self.gens[self.next_idx]
        self.next_idx = (self.next_idx + 1) % len(self.gens)
        return g.sequence(length)

    def batch(self, n, length):
        return np.stack([self.sequence(length) for _ in range(n)])
