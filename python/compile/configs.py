"""Model-zoo configurations — must mirror rust `model::config::ZooModel`."""

from dataclasses import dataclass


@dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    d_ff: int
    n_experts: int
    top_k: int
    n_shared: int
    n_heads: int
    vocab: int
    max_seq: int

    @property
    def head_dim(self):
        return self.d_model // self.n_heads


ZOO = {
    "mixtral-mini": ModelConfig("mixtral-mini", 4, 128, 256, 8, 2, 0, 4, 512, 512),
    "phi-mini": ModelConfig("phi-mini", 4, 128, 224, 16, 2, 0, 4, 512, 512),
    "deepseek-mini": ModelConfig("deepseek-mini", 4, 128, 64, 64, 6, 2, 4, 512, 512),
    "qwen-mini": ModelConfig("qwen-mini", 4, 128, 64, 60, 4, 4, 4, 512, 512),
}
