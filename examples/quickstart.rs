//! Quickstart: load a model, compress it with QESC, evaluate before/after.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use eac_moe::calib::qesc::{qesc_compress, QescConfig};
use eac_moe::coordinator::{load_or_init_model, ExperimentContext};
use eac_moe::model::ZooModel;

fn main() -> eac_moe::Result<()> {
    // 1. Load a pretrained mini model (falls back to random init if
    //    `make artifacts` hasn't been run).
    let (model, pretrained) = load_or_init_model(ZooModel::MixtralMini);
    println!(
        "loaded {} ({} params, {})",
        model.cfg().name,
        model.weights.param_count(),
        if pretrained { "pretrained" } else { "random-init" }
    );

    // 2. Calibration + eval data (the WikiText2 stand-in).
    let ctx = ExperimentContext::new(1, 0.3);

    // 3. Compress: GPTQ 3-bit experts + 4-bit MHSA + router calibration.
    let k = QescConfig::default_k(model.cfg());
    let (compressed, report) = qesc_compress(&model, &ctx.calib, &QescConfig::qesc(3, k));
    println!(
        "compressed {:.2} MB -> {:.2} MB ({:.2}x); router calib was {:.1}% of the time",
        report.fp_bytes as f64 / 1e6,
        report.compressed_bytes as f64 / 1e6,
        report.compression_ratio(),
        100.0 * report.router_calib_secs / (report.gptq_secs + report.router_calib_secs)
    );
    // The compressed model really is smaller in memory: experts stay packed
    // and run through the fused dequant GEMM.
    println!(
        "resident: {:.2} MB (experts {:.2} MB, vs {:.2} MB dense f32)",
        compressed.weights.storage_bytes() as f64 / 1e6,
        compressed.weights.expert_storage_bytes() as f64 / 1e6,
        model.weights.storage_bytes() as f64 / 1e6
    );

    // 4. Evaluate.
    let ppl_fp = eac_moe::eval::perplexity(&model, &ctx.ppl_eval);
    let ppl_q = eac_moe::eval::perplexity(&compressed, &ctx.ppl_eval);
    println!("perplexity: fp {ppl_fp:.2} -> compressed {ppl_q:.2}");

    // 5. PESF dynamic pruning at serve time (α = 0.3, the conservative
    //    sweet spot): just set one hook field.
    let (logits, stats) = eac_moe::prune::pesf::pesf_prefill(
        &compressed,
        &ctx.ppl_eval[0],
        eac_moe::prune::pesf::PesfConfig::conservative(),
    );
    println!(
        "PESF prefill: {} tokens, {:.1}% of experts pruned, logits {}x{}",
        ctx.ppl_eval[0].len(),
        stats.prune_rate() * 100.0,
        logits.rows,
        logits.cols
    );
    Ok(())
}
