//! END-TO-END DRIVER (DESIGN.md §5): the full system on a real workload.
//!
//! Loads the pretrained deepseek-mini, then runs every layer of the stack:
//!   1. baseline eval (PPL + zero-shot + serving latency),
//!   2. QESC compression (GPTQ 3-bit experts + router calibration),
//!   3. PESF(0.3) serving of batched requests through the engine,
//!   4. PJRT runtime check: executes the AOT expert-FFN artifact and
//!      cross-validates it against the native path (when artifacts exist),
//! and prints paper-style before/after rows. Results are recorded in
//! EXPERIMENTS.md.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_pipeline
//! ```

use eac_moe::calib::qesc::{qesc_compress, QescConfig};
use eac_moe::coordinator::{load_or_init_model, ExperimentContext};
use eac_moe::data::tasks::zero_shot_suite;
use eac_moe::model::hooks::Hooks;
use eac_moe::model::{Model, ZooModel};
use eac_moe::prune::pesf::PesfConfig;
use eac_moe::report::Table;
use eac_moe::runtime::{ArtifactManifest, RuntimeClient};
use eac_moe::serve::{Engine, EngineConfig, PrunePolicy, Request};
use eac_moe::tensor::Mat;

fn serve_latency(model: Model, prune: PrunePolicy, n: usize, len: usize) -> f64 {
    let engine = Engine::new(model, EngineConfig { workers: 1, prune, ..Default::default() });
    let mut mix = eac_moe::data::corpus::WikiMixture::new(77);
    let reqs: Vec<Request> =
        (0..n as u64).map(|i| Request::new(i, mix.sequence(len))).collect();
    let (_, m) = engine.serve(reqs);
    m.prefill.mean_ms()
}

fn main() -> eac_moe::Result<()> {
    let scale: f64 = std::env::var("E2E_SCALE").ok().and_then(|s| s.parse().ok()).unwrap_or(0.5);
    let zoo = ZooModel::DeepseekMini;
    let (fp, pretrained) = load_or_init_model(zoo);
    println!(
        "== EAC-MoE end-to-end on {} ({}) ==",
        zoo.display(),
        if pretrained { "pretrained" } else { "RANDOM INIT — run `make artifacts` first" }
    );
    let ctx = ExperimentContext::new(3, scale);
    let suite = zero_shot_suite((16.0 * scale) as usize + 4, 3);

    // ---- 1. Baseline.
    let t = std::time::Instant::now();
    let ppl_fp = eac_moe::eval::perplexity(&fp, &ctx.ppl_eval);
    let acc_fp = eac_moe::eval::eval_suite(&fp, &suite, Hooks::none);
    let lat_fp = serve_latency(Model::new(fp.weights.clone()), PrunePolicy::None, 4, 256);
    println!("[1] baseline measured in {:.1}s", t.elapsed().as_secs_f64());

    // ---- 2. QESC compression.
    let t = std::time::Instant::now();
    let k = QescConfig::default_k(fp.cfg());
    let (q, report) = qesc_compress(&fp, &ctx.calib, &QescConfig::qesc(3, k));
    println!(
        "[2] QESC in {:.1}s: {:.2} MB -> {:.2} MB ({:.2}x), router calib {:.1}%",
        t.elapsed().as_secs_f64(),
        report.fp_bytes as f64 / 1e6,
        report.compressed_bytes as f64 / 1e6,
        report.compression_ratio(),
        100.0 * report.router_calib_secs
            / (report.gptq_secs + report.router_calib_secs).max(1e-9),
    );
    let ppl_q = eac_moe::eval::perplexity(&q, &ctx.ppl_eval);
    let acc_q = eac_moe::eval::eval_suite(&q, &suite, Hooks::none);

    // ---- 3. PESF serving.
    let alpha = 0.3f32;
    let acc_qp = eac_moe::eval::eval_suite(&q, &suite, || Hooks {
        pesf_alpha: Some(alpha),
        ..Default::default()
    });
    let ppl_qp = eac_moe::eval::ppl::perplexity_with_hooks(&q, &ctx.ppl_eval, || Hooks {
        pesf_alpha: Some(alpha),
        ..Default::default()
    });
    let lat_qp = serve_latency(
        Model::new(q.weights.clone()),
        PrunePolicy::Pesf(PesfConfig { alpha, ..Default::default() }),
        4,
        256,
    );
    println!("[3] PESF(α={alpha}) served");

    // ---- 4. PJRT runtime round-trip (artifacts permitting).
    let root = ArtifactManifest::default_root();
    if ArtifactManifest::present(&root) {
        let client = RuntimeClient::new(ArtifactManifest::load(&root)?)?;
        let kind = format!("{}/expert_ffn", zoo.key());
        let exe = client.executable_for(&kind, 16)?;
        let bucket = exe.spec.bucket_m;
        let d = fp.cfg().d_model;
        let mut rng = eac_moe::tensor::Pcg64::seeded(9);
        let x = Mat::randn(bucket, d, 1.0, &mut rng);
        let e0 = &q.weights.layers[0].experts()[0];
        // QESC leaves experts packed; the f32 artifact takes dense inputs.
        let (w1, w2, w3) = (e0.w1.to_dense(), e0.w2.to_dense(), e0.w3.to_dense());
        let out = exe.run(&[&x, &w1, &w2, &w3])?[0].clone();
        let native = eac_moe::model::expert_forward(&x, e0);
        let max_err = out
            .data
            .iter()
            .zip(&native.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        println!(
            "[4] PJRT expert_ffn (bucket {bucket}) vs native: max err {max_err:.2e} on {}",
            client.platform()
        );
        assert!(max_err < 1e-3, "PJRT and native paths disagree");
    } else {
        println!("[4] artifacts/ absent — skipping PJRT check (run `make artifacts`)");
    }

    // ---- Summary.
    let mut table = Table::new(
        "EAC-MoE end-to-end summary (deepseek-mini)",
        &["stage", "Params(MB)", "PPL", "0-shot avg", "prefill ms", "speedup"],
    );
    // Measured resident bytes: QESC leaves experts packed, so this is the
    // real served footprint, not a simulated size.
    let fp_mb = fp.weights.storage_bytes() as f64 / 1e6;
    let q_mb = q.weights.storage_bytes() as f64 / 1e6;
    table.row(vec![
        "baseline (f32 resident)".into(),
        format!("{fp_mb:.2}"),
        format!("{ppl_fp:.2}"),
        format!("{:.2}", acc_fp.mean_accuracy()),
        format!("{lat_fp:.0}"),
        "1.00x".into(),
    ]);
    table.row(vec![
        "QESC 3-bit".into(),
        format!("{q_mb:.2}"),
        format!("{ppl_q:.2}"),
        format!("{:.2}", acc_q.mean_accuracy()),
        "-".into(),
        "-".into(),
    ]);
    table.row(vec![
        "QESC + PESF(0.3)".into(),
        format!("{q_mb:.2}"),
        format!("{ppl_qp:.2}"),
        format!("{:.2}", acc_qp.mean_accuracy()),
        format!("{lat_qp:.0}"),
        format!("{:.2}x", lat_fp / lat_qp),
    ]);
    table.print();
    Ok(())
}
