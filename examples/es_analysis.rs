//! Fig-2-style expert-selection study: task-typed routing preferences.
//!
//! Records expert-selection frequencies for a pretrained model over the 19
//! synthetic datasets, prints the intra/inter-family similarity summary and
//! the most/least used experts per task family (the Appendix A.11 view).
//!
//! ```bash
//! cargo run --release --example es_analysis [-- <model-key>]
//! ```

use eac_moe::coordinator::load_or_init_model;
use eac_moe::data::corpus::{TaskFamily, DATASETS};
use eac_moe::eval::es_analysis::{
    es_frequencies, es_similarity_matrix, intra_inter_summary, EsProfile,
};
use eac_moe::model::ZooModel;
use eac_moe::report::Table;

fn main() -> eac_moe::Result<()> {
    let key = std::env::args().nth(1).unwrap_or_else(|| "phi-mini".into());
    let zoo = ZooModel::from_key(&key).expect("unknown model key");
    let (model, pretrained) = load_or_init_model(zoo);
    if !pretrained {
        eprintln!("warning: random-init weights — run `make artifacts` for real structure");
    }
    let profiles: Vec<EsProfile> =
        DATASETS.iter().map(|d| es_frequencies(&model, d, 4, 96, 19)).collect();
    let sim = es_similarity_matrix(&profiles);
    let (intra, inter) = intra_inter_summary(&profiles, &sim);
    println!(
        "{}: intra-family mean cosine {intra:.3}, inter-family {inter:.3}\n",
        zoo.display()
    );

    // Per-family favorite experts (layer 1).
    let mut table = Table::new(
        "layer-1 expert preferences by task family",
        &["family", "top expert (freq)", "least expert (freq)", "balanced"],
    );
    for fam in TaskFamily::ALL {
        // Average the family's dataset profiles.
        let members: Vec<&EsProfile> =
            profiles.iter().filter(|p| p.family == fam.name()).collect();
        let n = model.cfg().n_experts;
        let mut avg = vec![0f32; n];
        for p in &members {
            for (a, &v) in avg.iter_mut().zip(&p.per_layer[1]) {
                *a += v / members.len() as f32;
            }
        }
        let top = eac_moe::tensor::ops::topk_indices(&avg, 1)[0];
        let neg: Vec<f32> = avg.iter().map(|&v| -v).collect();
        let least = eac_moe::tensor::ops::topk_indices(&neg, 1)[0];
        table.row(vec![
            fam.name().into(),
            format!("E{top} ({:.1}%)", avg[top] * 100.0),
            format!("E{least} ({:.2}%)", avg[least] * 100.0),
            format!("{:.2}%", 100.0 / n as f32),
        ]);
    }
    table.print();

    // The headline similarity pairs.
    let idx = |name: &str| profiles.iter().position(|p| p.dataset == name).unwrap();
    for (a, b) in [
        ("gsm8k", "mathqa"),
        ("gsm8k", "humaneval"),
        ("piqa", "hellaswag"),
        ("piqa", "lambada-fr"),
    ] {
        println!("sim({a}, {b}) = {:.3}", sim[idx(a)][idx(b)]);
    }
    Ok(())
}
