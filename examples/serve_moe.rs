//! Serving-engine demo: batched requests through the PESF-aware engine
//! with live metrics, comparing pruning policies side by side.
//!
//! ```bash
//! cargo run --release --example serve_moe [-- <alpha>]
//! ```

use eac_moe::coordinator::load_or_init_model;
use eac_moe::model::{Model, ZooModel};
use eac_moe::prune::ees::{calibrate_ees_threshold, EesPruner};
use eac_moe::prune::odp::OdpPruner;
use eac_moe::prune::pesf::PesfConfig;
use eac_moe::report::Table;
use eac_moe::serve::{BatchPolicy, Engine, EngineConfig, PrunePolicy, Request};

fn main() -> eac_moe::Result<()> {
    let alpha: f32 = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(0.5);
    let zoo = ZooModel::QwenMini;
    let (model, _) = load_or_init_model(zoo);
    println!("serving {} with policies: none / EES / ODP / PESF(α={alpha})", zoo.display());

    // Calibrate the token-level pruners on a small stream.
    let mut mix = eac_moe::data::corpus::WikiMixture::new(8);
    let calib = mix.sequences(4, 96);
    let ees = EesPruner { threshold: calibrate_ees_threshold(&model, &calib) };
    let odp = OdpPruner::calibrate(&model, &calib, 0.8);

    let policies: Vec<(&str, PrunePolicy)> = vec![
        ("none", PrunePolicy::None),
        ("EES", PrunePolicy::Ees(ees)),
        ("ODP", PrunePolicy::Odp(odp)),
        ("PESF", PrunePolicy::Pesf(PesfConfig { alpha, ..Default::default() })),
    ];
    let mut table = Table::new(
        "serving metrics (16 requests x 192 tokens + 16 decode, batch<=4, 1 worker)",
        &["policy", "thpt tok/s", "decode tok/s", "prefill p50 ms", "p95 ms", "prune", "decode prune"],
    );
    let mut base_thpt = 0.0;
    for (name, policy) in policies {
        let engine = Engine::new(
            Model::new(model.weights.clone()),
            EngineConfig {
                batch: BatchPolicy::default(),
                workers: 1,
                prune: policy,
                ..Default::default()
            },
        );
        let mut mix = eac_moe::data::corpus::WikiMixture::new(9);
        // Decode requests ride the single-pass prefill (KV export) and the
        // batched decode loop — under PESF each sequence's mask follows it
        // into decode and refreshes from a rolling frequency window.
        let reqs: Vec<Request> =
            (0..16u64).map(|i| Request::new(i, mix.sequence(192)).with_decode(16)).collect();
        let (_, m) = engine.serve(reqs);
        if name == "none" {
            base_thpt = m.throughput_tokens_per_sec();
        }
        table.row(vec![
            format!(
                "{name}{}",
                if name == "none" {
                    String::new()
                } else {
                    format!(" ({:.2}x)", m.throughput_tokens_per_sec() / base_thpt)
                }
            ),
            format!("{:.0}", m.throughput_tokens_per_sec()),
            format!("{:.0}", m.decode_tokens_per_sec()),
            format!("{:.1}", m.prefill.percentile_ms(0.5)),
            format!("{:.1}", m.prefill.percentile_ms(0.95)),
            format!("{:.1}%", m.mean_prune_rate * 100.0),
            format!("{:.1}%", m.mean_decode_prune_rate * 100.0),
        ]);
    }
    table.print();
    Ok(())
}
