//! Sweep: compress every zoo model at every bit setting, print the memory /
//! PPL landscape (a condensed Table-2/4 view).
//!
//! ```bash
//! cargo run --release --example compress_zoo
//! ```

use eac_moe::coordinator::{load_or_init_model, ExperimentContext};
use eac_moe::model::ZooModel;
use eac_moe::report::exp_common::{compress, BitSetting, QuantMethod};
use eac_moe::report::Table;

fn main() -> eac_moe::Result<()> {
    let ctx = ExperimentContext::new(13, 0.25);
    let mut table = Table::new(
        "compression landscape (QESC) — MB is measured resident bytes",
        &["model", "bits", "MB", "ratio vs f32", "PPL fp", "PPL q", "avg expert bits"],
    );
    for zoo in ZooModel::ALL {
        let (fp, _) = load_or_init_model(zoo);
        let ppl_fp = eac_moe::eval::perplexity(&fp, &ctx.ppl_eval);
        for bits in BitSetting::ALL {
            let (q, report) = compress(&fp, zoo, QuantMethod::Qesc, bits, &ctx);
            let ppl_q = eac_moe::eval::perplexity(&q, &ctx.ppl_eval);
            // Measured resident bytes of the packed model, not simulated.
            let q_mb = q.weights.storage_bytes() as f64 / 1e6;
            let fp_mb = fp.weights.storage_bytes() as f64 / 1e6;
            table.row(vec![
                zoo.key().into(),
                bits.label().into(),
                format!("{q_mb:.2}"),
                format!("{:.2}x", fp_mb / q_mb),
                format!("{ppl_fp:.2}"),
                format!("{ppl_q:.2}"),
                format!("{:.2}", report.avg_expert_bits),
            ]);
        }
    }
    table.print();
    Ok(())
}
